//! Rating deltas: a batch of new/updated cells, its projection onto the
//! block grid, and the `ingest --append` fold into an on-disk store.
//!
//! A [`RatingDelta`] is the unit of online change: cells collected since
//! the last (re)train, as *raw* (uncentred) values, optionally reaching
//! row/column ids the trained matrix has never seen. Two consumers:
//!
//! - [`RatingDelta::apply_to`] upserts the delta into a resident `Coo` —
//!   existing cells are replaced **in place** (entry order preserved),
//!   new cells are appended at the end in delta order. That ordering
//!   contract is what makes the resident and store-backed update paths
//!   produce bitwise-identical per-block entry sequences.
//! - [`append_delta`] folds the delta into an ingested shard store:
//!   only dirty shards are rewritten (atomic temp + rename, the PR-7
//!   discipline), the manifest's [`revision`](crate::store::Manifest)
//!   is bumped by exactly one, and the persisted centring mean is left
//!   untouched — the store keeps centring with the mean its checkpoints
//!   were trained under, so clean blocks stay bitwise clean.
//!
//! Deltas that *grow* the matrix (new users/items) move every block
//! boundary, so they degrade gracefully: [`RatingDelta::dirty_blocks`]
//! reports every block dirty (an update then retrains fully, inside the
//! same API) and [`append_delta`] rewrites every shard on the new grid.

use crate::data::sparse::{Coo, Entry};
use crate::partition::Grid;
use crate::store::manifest::{atomic_write, fnv1a64, Manifest, StoreError, RECORD_BYTES};
use crate::store::shard::encode_block;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::Path;

/// A batch of new or corrected ratings, in raw (uncentred) scale.
///
/// `rows`/`cols` are the dimensions the delta *requires*: the max index
/// + 1 over its entries (or whatever larger shape the caller declares).
/// When they exceed the trained matrix the delta introduces new ids.
#[derive(Debug, Clone, Default)]
pub struct RatingDelta {
    /// Row count the delta requires of the matrix it applies to.
    pub rows: usize,
    /// Column count the delta requires of the matrix it applies to.
    pub cols: usize,
    /// The delta cells, in arrival order. A later entry for the same
    /// cell wins over an earlier one (upsert order).
    pub entries: Vec<Entry>,
}

impl RatingDelta {
    /// An empty delta constrained to a `rows` × `cols` matrix.
    pub fn new(rows: usize, cols: usize) -> RatingDelta {
        RatingDelta { rows, cols, entries: Vec::new() }
    }

    /// A delta holding every entry of `data` (e.g. a loaded delta CSV).
    pub fn from_coo(data: &Coo) -> RatingDelta {
        RatingDelta { rows: data.rows, cols: data.cols, entries: data.entries.clone() }
    }

    /// Append one cell, growing the declared dimensions to contain it.
    pub fn push(&mut self, row: usize, col: usize, val: f32) {
        self.rows = self.rows.max(row + 1);
        self.cols = self.cols.max(col + 1);
        self.entries.push(Entry { row: row as u32, col: col as u32, val });
    }

    /// Number of delta cells.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the delta holds no cells (and so dirties no blocks).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True when the delta reaches row/column ids outside `rows` × `cols`
    /// — applying it grows the matrix and moves every block boundary.
    pub fn grows(&self, rows: usize, cols: usize) -> bool {
        self.rows > rows || self.cols > cols
    }

    /// Upsert the delta into `base`: existing cells are replaced in
    /// place (preserving `base`'s entry order), new cells are appended
    /// at the end in delta order, and the dimensions grow to the max of
    /// both. When a cell appears more than once in `base` the *last*
    /// occurrence is the one replaced — the same convention
    /// [`append_delta`] applies per shard, which keeps the two update
    /// paths bitwise-aligned.
    pub fn apply_to(&self, base: &Coo) -> Coo {
        let mut out = base.clone();
        out.rows = base.rows.max(self.rows);
        out.cols = base.cols.max(self.cols);
        let mut index: HashMap<(u32, u32), usize> =
            out.entries.iter().enumerate().map(|(n, e)| ((e.row, e.col), n)).collect();
        for e in &self.entries {
            match index.entry((e.row, e.col)) {
                std::collections::hash_map::Entry::Occupied(o) => {
                    out.entries[*o.get()].val = e.val;
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(out.entries.len());
                    out.entries.push(*e);
                }
            }
        }
        out
    }

    /// Project the delta through `grid` onto canonical block indices:
    /// the set of blocks an incremental update must re-sample. Routing
    /// uses [`Grid::block_of`] — the exact arithmetic of
    /// [`Grid::split`] — so a dirty set plus the clean complement is
    /// always a partition of the grid. A delta that grows past the
    /// grid's dimensions dirties **every** block (growth moves block
    /// boundaries, so no block's membership is stable).
    pub fn dirty_blocks(&self, grid: &Grid) -> BTreeSet<(usize, usize)> {
        let mut out = BTreeSet::new();
        if self.grows(grid.rows, grid.cols) {
            for i in 0..grid.i_blocks {
                for j in 0..grid.j_blocks {
                    out.insert((i, j));
                }
            }
            return out;
        }
        for e in &self.entries {
            let id = grid.block_of(e.row as usize, e.col as usize);
            out.insert((id.i, id.j));
        }
        out
    }
}

/// Summary of a completed [`append_delta`], for CLI reporting.
#[derive(Debug, Clone)]
pub struct AppendReport {
    /// The store's revision after the append (`previous + 1`).
    pub revision: u64,
    /// Shard files rewritten (the dirty blocks; all of them when the
    /// delta grew the matrix).
    pub rewritten: usize,
    /// Delta cells folded in.
    pub delta_nnz: usize,
    /// Total ratings in the store after the append.
    pub nnz: usize,
    /// Matrix shape after the append.
    pub shape: (usize, usize),
    /// True when the delta grew the matrix (every shard was rewritten
    /// on the re-derived grid).
    pub grown: bool,
}

/// Decode a shard file's 12-byte LE records into a block-local, raw
/// (uncentred) `Coo` — the writer-side inverse of `encode_block`, needed
/// here because the reader path (`ShardStore::read_block`) centres.
fn decode_raw(bytes: &[u8], rows: usize, cols: usize) -> Coo {
    let mut coo = Coo::new(rows, cols);
    coo.entries.reserve(bytes.len() / RECORD_BYTES as usize);
    for rec in bytes.chunks_exact(RECORD_BYTES as usize) {
        coo.entries.push(Entry {
            row: u32::from_le_bytes(rec[0..4].try_into().expect("4-byte slice")),
            col: u32::from_le_bytes(rec[4..8].try_into().expect("4-byte slice")),
            val: f32::from_le_bytes(rec[8..12].try_into().expect("4-byte slice")),
        });
    }
    coo
}

/// Read shard `(i, j)`'s raw bytes, verifying size and checksum against
/// the manifest — corruption fails the append typed, before any write.
fn read_shard_raw(dir: &Path, manifest: &Manifest, idx: usize) -> Result<Vec<u8>, StoreError> {
    let meta = &manifest.shards[idx];
    let path = dir.join(&meta.file);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(source) if source.kind() == std::io::ErrorKind::NotFound => {
            return Err(StoreError::MissingShard { path })
        }
        Err(source) => return Err(StoreError::Io { path, source }),
    };
    if bytes.len() as u64 != meta.bytes() {
        return Err(StoreError::SizeMismatch {
            path,
            expected: meta.bytes(),
            found: bytes.len() as u64,
        });
    }
    let found = fnv1a64(&bytes);
    if found != meta.checksum {
        return Err(StoreError::ChecksumMismatch { path, expected: meta.checksum, found });
    }
    Ok(bytes)
}

/// Fold `delta` into the ingested store at `dir` — the `bmf-pp ingest
/// --append` engine.
///
/// Same-shape deltas rewrite **only** the dirty shards: each is read
/// back (size + checksum verified), upserted in block-local coordinates
/// with [`RatingDelta::apply_to`]'s exact ordering convention, and
/// atomically replaced; clean shards are never touched. A delta that
/// grows the matrix rewrites every shard on the grid re-derived for the
/// new shape (same block counts). Either way the manifest's `revision`
/// is bumped by exactly one and its `global_mean` is left unchanged —
/// the centring mean is pinned at first ingest so checkpoints seeded
/// from this store stay bitwise-valid priors.
pub fn append_delta(delta: &RatingDelta, dir: &Path) -> Result<AppendReport, StoreError> {
    let mut manifest = Manifest::load(dir)?;
    let (gi, gj) = manifest.grid;
    let grown = delta.grows(manifest.rows, manifest.cols);
    let rewritten = if grown {
        append_grown(delta, dir, &mut manifest)?
    } else {
        append_in_place(delta, dir, &mut manifest)?
    };
    manifest.nnz = manifest.shards.iter().map(|s| s.nnz).sum();
    manifest.revision += 1;
    manifest.save(dir)?;
    debug_assert_eq!(manifest.shards.len(), gi * gj);
    Ok(AppendReport {
        revision: manifest.revision,
        rewritten,
        delta_nnz: delta.len(),
        nnz: manifest.nnz,
        shape: (manifest.rows, manifest.cols),
        grown,
    })
}

/// Same-shape append: upsert into dirty shards only.
fn append_in_place(
    delta: &RatingDelta,
    dir: &Path,
    manifest: &mut Manifest,
) -> Result<usize, StoreError> {
    let (gi, gj) = manifest.grid;
    let grid = Grid::new(manifest.rows, manifest.cols, gi, gj);
    // group delta cells by block, preserving delta order within each
    let mut by_block: BTreeMap<(usize, usize), Vec<Entry>> = BTreeMap::new();
    for e in &delta.entries {
        let id = grid.block_of(e.row as usize, e.col as usize);
        by_block.entry((id.i, id.j)).or_default().push(*e);
    }
    for (&(i, j), cells) in &by_block {
        let idx = i * gj + j;
        let (brows, bcols) = (manifest.shards[idx].rows, manifest.shards[idx].cols);
        let bytes = read_shard_raw(dir, manifest, idx)?;
        let mut block = decode_raw(&bytes, brows, bcols);
        let (r0, _) = grid.row_range(i);
        let (c0, _) = grid.col_range(j);
        // last duplicate wins on collision — apply_to's convention
        let mut index: HashMap<(u32, u32), usize> =
            block.entries.iter().enumerate().map(|(n, e)| ((e.row, e.col), n)).collect();
        for e in cells {
            let local = (e.row - r0 as u32, e.col - c0 as u32);
            match index.entry(local) {
                std::collections::hash_map::Entry::Occupied(o) => {
                    block.entries[*o.get()].val = e.val;
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(block.entries.len());
                    block.entries.push(Entry { row: local.0, col: local.1, val: e.val });
                }
            }
        }
        let new_bytes = encode_block(&block);
        atomic_write(&dir.join(&manifest.shards[idx].file), &new_bytes)?;
        manifest.shards[idx].nnz = block.nnz();
        manifest.shards[idx].checksum = fnv1a64(&new_bytes);
    }
    Ok(by_block.len())
}

/// Growth append: reconstruct the full raw matrix (block-major — the
/// per-block entry order, which is all training ever sees, is preserved
/// exactly), upsert, and re-split on the grid derived for the new shape.
fn append_grown(
    delta: &RatingDelta,
    dir: &Path,
    manifest: &mut Manifest,
) -> Result<usize, StoreError> {
    use crate::partition::grid::BlockId;
    use crate::store::manifest::shard_file_name;
    use crate::store::ShardMeta;
    let (gi, gj) = manifest.grid;
    let old_grid = Grid::new(manifest.rows, manifest.cols, gi, gj);
    let mut base = Coo::new(manifest.rows, manifest.cols);
    for idx in 0..manifest.shards.len() {
        let meta = manifest.shards[idx].clone();
        let bytes = read_shard_raw(dir, manifest, idx)?;
        let (r0, _) = old_grid.row_range(meta.i);
        let (c0, _) = old_grid.col_range(meta.j);
        for e in decode_raw(&bytes, meta.rows, meta.cols).entries {
            base.entries.push(Entry {
                row: e.row + r0 as u32,
                col: e.col + c0 as u32,
                val: e.val,
            });
        }
    }
    let updated = delta.apply_to(&base);
    if gi > updated.rows || gj > updated.cols {
        // unreachable for growth, but keep the typed guard
        return Err(StoreError::InvalidGrid { gi, gj, rows: updated.rows, cols: updated.cols });
    }
    let new_grid = Grid::new(updated.rows, updated.cols, gi, gj);
    let blocks = new_grid.split(&updated);
    let mut shards = Vec::with_capacity(gi * gj);
    for (i, row) in blocks.iter().enumerate() {
        for (j, block) in row.iter().enumerate() {
            let bytes = encode_block(block);
            let file = shard_file_name(i, j);
            atomic_write(&dir.join(&file), &bytes)?;
            let (rows, cols) = new_grid.block_shape(BlockId { i, j });
            shards.push(ShardMeta {
                i,
                j,
                rows,
                cols,
                nnz: block.nnz(),
                checksum: fnv1a64(&bytes),
                file,
            });
        }
    }
    manifest.rows = updated.rows;
    manifest.cols = updated.cols;
    manifest.shards = shards;
    Ok(gi * gj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{ingest, ShardStore};
    use std::path::PathBuf;

    fn toy() -> Coo {
        let mut c = Coo::new(6, 5);
        for (r, col, v) in
            [(0, 0, 1.0), (1, 3, 2.5), (2, 2, -0.5), (3, 4, 4.0), (5, 1, 3.0), (5, 4, 0.25)]
        {
            c.push(r, col, v as f32);
        }
        c
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("bmfpp_online_delta_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn dirty_blocks_match_split_membership() {
        let data = toy();
        let grid = Grid::new(6, 5, 3, 2);
        let delta = RatingDelta::from_coo(&data);
        let dirty = delta.dirty_blocks(&grid);
        let blocks = grid.split(&data);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(
                    dirty.contains(&(i, j)),
                    blocks[i][j].nnz() > 0,
                    "block ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn growth_dirties_every_block() {
        let grid = Grid::new(6, 5, 2, 2);
        let mut delta = RatingDelta::new(0, 0);
        delta.push(6, 0, 1.0); // row 6 is outside a 6-row matrix
        assert_eq!(delta.dirty_blocks(&grid).len(), 4);
    }

    #[test]
    fn apply_to_upserts_in_place_and_appends_new_at_end() {
        let base = toy();
        let mut delta = RatingDelta::new(6, 5);
        delta.push(1, 3, 9.0); // replaces base entry #1 in place
        delta.push(4, 4, 7.0); // new cell, appended at the end
        let out = delta.apply_to(&base);
        assert_eq!((out.rows, out.cols), (6, 5));
        assert_eq!(out.nnz(), base.nnz() + 1);
        assert_eq!(out.entries[1], Entry { row: 1, col: 3, val: 9.0 });
        assert_eq!(*out.entries.last().unwrap(), Entry { row: 4, col: 4, val: 7.0 });
        // untouched entries keep their exact position and bits
        assert_eq!(out.entries[0], base.entries[0]);
        assert_eq!(out.entries[2..6], base.entries[2..6]);
    }

    #[test]
    fn apply_to_last_delta_entry_wins() {
        let base = toy();
        let mut delta = RatingDelta::new(6, 5);
        delta.push(4, 4, 1.0);
        delta.push(4, 4, 2.0); // same new cell twice: later wins, once
        let out = delta.apply_to(&base);
        assert_eq!(out.nnz(), base.nnz() + 1);
        assert_eq!(*out.entries.last().unwrap(), Entry { row: 4, col: 4, val: 2.0 });
    }

    #[test]
    fn append_rewrites_only_dirty_shards_and_bumps_revision() {
        let data = toy();
        let dir = temp_dir("dirty_only");
        ingest(&data, 2, 2, &dir).unwrap();
        let before: Vec<Vec<u8>> = (0..2)
            .flat_map(|i| (0..2).map(move |j| (i, j)))
            .map(|(i, j)| {
                std::fs::read(dir.join(crate::store::manifest::shard_file_name(i, j))).unwrap()
            })
            .collect();
        // one cell in block (0,0) only (rows 0..3, cols 0..3 of 6x5 / 2x2)
        let mut delta = RatingDelta::new(6, 5);
        delta.push(0, 0, 5.0);
        let report = append_delta(&delta, &dir).unwrap();
        assert_eq!(report.revision, 1);
        assert_eq!(report.rewritten, 1);
        assert!(!report.grown);
        assert_eq!(report.nnz, data.nnz(), "an upsert of an existing cell adds no entry");
        let after: Vec<Vec<u8>> = (0..2)
            .flat_map(|i| (0..2).map(move |j| (i, j)))
            .map(|(i, j)| {
                std::fs::read(dir.join(crate::store::manifest::shard_file_name(i, j))).unwrap()
            })
            .collect();
        assert_ne!(before[0], after[0], "dirty shard (0,0) must change");
        assert_eq!(before[1..], after[1..], "clean shards must be byte-identical");
        // the store still opens (sizes + checksums consistent)
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!(store.revision(), 1);
        assert_eq!(store.global_mean().to_bits(), data.mean().to_bits(), "mean is pinned");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_matches_resident_apply_per_block() {
        let data = toy();
        let dir = temp_dir("equivalence");
        ingest(&data, 2, 2, &dir).unwrap();
        let mut delta = RatingDelta::new(6, 5);
        delta.push(1, 3, 9.0); // update in block (0,1)
        delta.push(4, 0, -2.0); // new cell in block (1,0)
        append_delta(&delta, &dir).unwrap();

        // resident reference: upsert, centre by the PINNED mean, split
        let updated = delta.apply_to(&data);
        let mean = data.mean();
        let mut centred = updated.clone();
        for e in &mut centred.entries {
            e.val -= mean as f32;
        }
        let expect = Grid::new(6, 5, 2, 2).split(&centred);

        let store = ShardStore::open(&dir).unwrap();
        for i in 0..2 {
            for j in 0..2 {
                let got = store.read_block(i, j).unwrap();
                assert_eq!(got.coo.entries, expect[i][j].entries, "block ({i},{j})");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grown_append_rewrites_all_on_new_grid_keeping_the_mean() {
        let data = toy();
        let dir = temp_dir("grown");
        ingest(&data, 2, 2, &dir).unwrap();
        let mut delta = RatingDelta::new(0, 0);
        delta.push(7, 5, 2.0); // grows to 8 rows x 6 cols
        let report = append_delta(&delta, &dir).unwrap();
        assert!(report.grown);
        assert_eq!(report.rewritten, 4);
        assert_eq!(report.shape, (8, 6));
        assert_eq!(report.nnz, data.nnz() + 1);
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!((store.rows(), store.cols()), (8, 6));
        assert_eq!(store.revision(), 1);
        assert_eq!(
            store.global_mean().to_bits(),
            data.mean().to_bits(),
            "growth must not re-derive the centring mean"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_fails_append_typed_before_writing() {
        let data = toy();
        let dir = temp_dir("corrupt");
        ingest(&data, 2, 2, &dir).unwrap();
        let shard = dir.join(crate::store::manifest::shard_file_name(0, 0));
        let mut bytes = std::fs::read(&shard).unwrap();
        bytes[0] ^= 0xff;
        std::fs::write(&shard, &bytes).unwrap();
        let mut delta = RatingDelta::new(6, 5);
        delta.push(0, 0, 5.0);
        let err = append_delta(&delta, &dir).unwrap_err();
        assert!(matches!(err, StoreError::ChecksumMismatch { .. }), "{err}");
        // manifest untouched: revision still 0
        assert_eq!(Manifest::load(&dir).unwrap().revision, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
