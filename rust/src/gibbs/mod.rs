//! Pure-rust BPMF Gibbs sampler.
//!
//! Two roles:
//! 1. **Oracle**: the runtime's AOT HLO path is cross-checked against this
//!    implementation on identical inputs (same injected noise).
//! 2. **Baseline**: the "BMF" column of the paper's Table 3 (plain BPMF,
//!    1×1 grid, no PP) runs through this sampler.
//!
//! The Normal-Wishart hyperparameter updates (hyper.rs) run in rust in both
//! the native and the HLO-backed samplers — they are K×K-cheap and once per
//! sweep, not part of the hot path.

pub mod hyper;
pub mod native;

pub use hyper::{NormalWishartPrior, sample_hyper};
pub use native::{sample_side_native, GibbsPrecision, NativeGibbs, RowSampler, SampleError};
