//! Normal-Wishart hyperparameter sampling (BPMF, Salakhutdinov & Mnih 2008).
//!
//! Conditional on the current factor matrix U (N rows of dim K), the
//! hyperparameters (mu, Lambda) of the row prior N(mu, Lambda^{-1}) are
//! sampled from their Normal-Wishart conditional:
//!
//!   Lambda ~ W(W*, nu0 + N)
//!   mu | Lambda ~ N(mu*, (beta0 + N) Lambda)^{-1}
//!
//! with the standard posterior updates of (mu0, beta0, W0, nu0).

use crate::linalg::{Cholesky, Mat};
use crate::rng::{normal::StdNormal, wishart::sample_wishart, Rng};

/// Normal-Wishart prior parameters.
#[derive(Debug, Clone)]
pub struct NormalWishartPrior {
    /// Prior mean of the row-prior mean.
    pub mu0: Vec<f64>,
    /// Mean-precision scaling.
    pub beta0: f64,
    /// W0 scale matrix.
    pub w0: Mat,
    /// Wishart degrees of freedom.
    pub nu0: f64,
}

impl NormalWishartPrior {
    /// The BPMF defaults: mu0 = 0, beta0 = 2, W0 = I, nu0 = K.
    pub fn default_for_dim(k: usize) -> NormalWishartPrior {
        NormalWishartPrior { mu0: vec![0.0; k], beta0: 2.0, w0: Mat::eye(k), nu0: k as f64 }
    }
}

/// Sampled hyperparameters: row-prior mean and precision.
#[derive(Debug, Clone)]
pub struct HyperSample {
    /// Sampled row-prior mean.
    pub mu: Vec<f64>,
    /// Sampled row-prior precision.
    pub lambda: Mat,
}

/// Draw (mu, Lambda) conditional on factor rows `u` (row-major n × k).
pub fn sample_hyper(
    rng: &mut Rng,
    prior: &NormalWishartPrior,
    u: &[f64],
    n: usize,
    k: usize,
) -> HyperSample {
    assert_eq!(u.len(), n * k);
    let nf = n as f64;

    // sample mean and scatter
    let mut ubar = vec![0.0; k];
    for i in 0..n {
        for j in 0..k {
            ubar[j] += u[i * k + j];
        }
    }
    if n > 0 {
        for j in ubar.iter_mut() {
            *j /= nf;
        }
    }
    let mut scatter = Mat::zeros(k, k);
    for i in 0..n {
        let row = &u[i * k..(i + 1) * k];
        for a in 0..k {
            for b in 0..k {
                scatter[(a, b)] += (row[a] - ubar[a]) * (row[b] - ubar[b]);
            }
        }
    }

    // posterior Normal-Wishart params
    let beta_n = prior.beta0 + nf;
    let nu_n = prior.nu0 + nf;
    let mut mu_n = vec![0.0; k];
    for j in 0..k {
        mu_n[j] = (prior.beta0 * prior.mu0[j] + nf * ubar[j]) / beta_n;
    }
    // W_n^{-1} = W0^{-1} + S + beta0*N/(beta0+N) (ubar-mu0)(ubar-mu0)^T
    let w0_inv = Cholesky::new(&prior.w0).expect("W0 SPD").inverse();
    let mut wn_inv = w0_inv;
    wn_inv.add_scaled(&scatter, 1.0);
    let diff: Vec<f64> = (0..k).map(|j| ubar[j] - prior.mu0[j]).collect();
    wn_inv.add_scaled(&Mat::outer(&diff, &diff), prior.beta0 * nf / beta_n);
    wn_inv.symmetrize();
    let wn = Cholesky::new(&wn_inv).expect("Wn^{-1} SPD").inverse();

    // Lambda ~ W(Wn, nu_n)
    let lambda = sample_wishart(rng, &wn, nu_n);

    // mu ~ N(mu_n, (beta_n Lambda)^{-1})
    let mut prec = lambda.clone();
    prec.scale(beta_n);
    let chol = Cholesky::new(&prec).expect("beta_n*Lambda SPD");
    let mut norm = StdNormal::new();
    let eps: Vec<f64> = (0..k).map(|_| norm.sample(rng)).collect();
    let mu = chol.sample_with_precision(&mu_n, &eps);

    HyperSample { mu, lambda }
}

/// Gamma(a0, b0) prior on the residual precision τ; conditional on the
/// current factors the posterior is Gamma(a0 + n/2, b0 + SSE/2) — sampling
/// τ instead of fixing it is the standard BPMF extension (the paper fixes
/// τ; `TrainConfig::tau` / `auto_tau` covers that path).
pub fn sample_tau(rng: &mut Rng, a0: f64, b0: f64, sse: f64, n_obs: usize) -> f64 {
    let shape = a0 + n_obs as f64 / 2.0;
    let rate = b0 + sse / 2.0;
    crate::rng::gamma::Gamma::new(shape, 1.0 / rate).sample(rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::normal::StdNormal;

    #[test]
    fn tau_posterior_concentrates_on_true_precision() {
        // residuals from N(0, 1/tau*) with lots of data → τ draws ≈ τ*
        let tau_star: f64 = 4.0;
        let n = 50_000;
        let mut rng = Rng::seed_from_u64(41);
        let mut norm = StdNormal::new();
        let sse: f64 = (0..n)
            .map(|_| {
                let e = norm.sample(&mut rng) / tau_star.sqrt();
                e * e
            })
            .sum();
        let mean_tau: f64 = (0..200)
            .map(|_| sample_tau(&mut rng, 1.0, 1.0, sse, n))
            .sum::<f64>()
            / 200.0;
        assert!(
            (mean_tau - tau_star).abs() / tau_star < 0.05,
            "tau {mean_tau} vs {tau_star}"
        );
    }

    #[test]
    fn tau_prior_dominates_with_no_data() {
        let mut rng = Rng::seed_from_u64(42);
        // Gamma(2, rate 1) mean = 2
        let mean: f64 =
            (0..5000).map(|_| sample_tau(&mut rng, 2.0, 1.0, 0.0, 0)).sum::<f64>() / 5000.0;
        assert!((mean - 2.0).abs() < 0.1, "prior mean {mean}");
    }

    #[test]
    fn recovers_generating_hyperparams_in_expectation() {
        // generate rows from N(mu*, sigma^2 I); posterior mean of mu should
        // approach mu*, and Lambda's mean diag should approach 1/sigma^2.
        let k = 4;
        let n = 2000;
        let mu_star = [1.0, -0.5, 0.25, 2.0];
        let sigma = 0.7;
        let mut rng = Rng::seed_from_u64(17);
        let mut norm = StdNormal::new();
        let mut u = vec![0.0; n * k];
        for i in 0..n {
            for j in 0..k {
                u[i * k + j] = mu_star[j] + sigma * norm.sample(&mut rng);
            }
        }
        let prior = NormalWishartPrior::default_for_dim(k);
        // average several draws to tame MC noise
        let mut mu_acc = vec![0.0; k];
        let mut lam_acc = Mat::zeros(k, k);
        let draws = 200;
        for _ in 0..draws {
            let h = sample_hyper(&mut rng, &prior, &u, n, k);
            for j in 0..k {
                mu_acc[j] += h.mu[j] / draws as f64;
            }
            lam_acc.add_scaled(&h.lambda, 1.0 / draws as f64);
        }
        for j in 0..k {
            assert!((mu_acc[j] - mu_star[j]).abs() < 0.1, "mu[{j}]={}", mu_acc[j]);
        }
        let want_prec = 1.0 / (sigma * sigma);
        for j in 0..k {
            assert!(
                (lam_acc[(j, j)] - want_prec).abs() / want_prec < 0.15,
                "lambda[{j}]={} want {want_prec}",
                lam_acc[(j, j)]
            );
        }
    }

    #[test]
    fn handles_empty_factor_matrix() {
        let k = 3;
        let mut rng = Rng::seed_from_u64(5);
        let prior = NormalWishartPrior::default_for_dim(k);
        let h = sample_hyper(&mut rng, &prior, &[], 0, k);
        assert_eq!(h.mu.len(), k);
        assert!(Cholesky::new(&h.lambda).is_ok());
    }

    #[test]
    fn lambda_draws_are_spd() {
        let k = 8;
        let mut rng = Rng::seed_from_u64(6);
        let prior = NormalWishartPrior::default_for_dim(k);
        let mut norm = StdNormal::new();
        let n = 50;
        let u: Vec<f64> = (0..n * k).map(|_| norm.sample(&mut rng)).collect();
        for _ in 0..20 {
            let h = sample_hyper(&mut rng, &prior, &u, n, k);
            assert!(Cholesky::new(&h.lambda).is_ok());
        }
    }
}
