//! Pure-rust BPMF Gibbs half-sweep — the oracle for the AOT HLO path and
//! the plain-BMF baseline sampler.
//!
//! `sample_side_native` implements EXACTLY the math of
//! python/compile/model.py::sample_side, consuming the same injected noise,
//! so the two paths can be compared bit-for-tolerance on identical inputs.

use crate::data::sparse::Csr;
use crate::linalg::{Cholesky, Mat};
use crate::posterior::RowGaussians;
use crate::rng::{normal::standard_normal_vec, Rng};

/// One conditional Gibbs update of the N rows of one side, given the D
/// opposite-side factor rows `v` (row-major d × k, f32 like the runtime).
///
/// Returns (samples, conditional means), both row-major n × k f32.
pub fn sample_side_native(
    csr: &Csr,
    v: &[f32],
    k: usize,
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let n = csr.rows;
    let mut samples = vec![0.0f32; n * k];
    let mut means = vec![0.0f32; n * k];
    sample_rows_into(csr, 0..n, v, k, prior, tau, noise, &mut samples, &mut means);
    (samples, means)
}

/// The chunked core of [`sample_side_native`]: update only the rows in
/// `rows` (global indices into `csr`/`prior`/`noise`), writing the
/// results into the chunk-local `samples`/`means` buffers (each
/// `rows.len() × k`). Rows are conditionally independent given `v`, so a
/// chunk's output is bitwise identical whether it is sampled alone (the
/// pipelined sweep's publish unit) or as part of a full half-sweep.
#[allow(clippy::too_many_arguments)]
pub fn sample_rows_into(
    csr: &Csr,
    rows: std::ops::Range<usize>,
    v: &[f32],
    k: usize,
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
    samples: &mut [f32],
    means: &mut [f32],
) {
    let n = csr.rows;
    assert_eq!(prior.n, n);
    assert_eq!(prior.k, k);
    assert_eq!(noise.len(), n * k);
    assert_eq!(v.len(), csr.cols * k);
    assert!(rows.end <= n, "row range exceeds the side");
    assert_eq!(samples.len(), rows.len() * k);
    assert_eq!(means.len(), rows.len() * k);

    let row0 = rows.start;
    let mut prec = Mat::zeros(k, k);
    let mut rhs = vec![0.0f64; k];

    for i in rows {
        // start from the prior's natural parameters
        prec.data.copy_from_slice(&prior.prec[i * k * k..(i + 1) * k * k]);
        let pm = prior.row_mean(i);
        let prior_prec = prior.row_prec(i);
        let h = prior_prec.matvec(pm);
        rhs.copy_from_slice(&h);

        // accumulate observed items: prec += tau * v_d v_d^T, rhs += tau r v_d.
        // v_d v_d^T is symmetric — accumulate the upper triangle only and
        // mirror once per row (≈2x on the K² hot term).
        let (cols, vals) = csr.row(i);
        for (c, r) in cols.iter().zip(vals) {
            let vd = &v[*c as usize * k..(*c as usize + 1) * k];
            for a in 0..k {
                let va = tau * vd[a] as f64;
                let pa = &mut prec.data[a * k + a..(a + 1) * k];
                for (pv, &vb) in pa.iter_mut().zip(&vd[a..]) {
                    *pv += va * vb as f64;
                }
                rhs[a] += (*r as f64) * va;
            }
        }
        for a in 1..k {
            for b in 0..a {
                prec.data[a * k + b] = prec.data[b * k + a];
            }
        }

        let chol = Cholesky::new(&prec).expect("posterior precision SPD");
        let mean = chol.solve(&rhs);
        let eps: Vec<f64> = noise[i * k..(i + 1) * k].iter().map(|&x| x as f64).collect();
        let draw = chol.sample_with_precision(&mean, &eps);
        let local = (i - row0) * k;
        for j in 0..k {
            samples[local + j] = draw[j] as f32;
            means[local + j] = mean[j] as f32;
        }
    }
}

/// Plain-BPMF Gibbs sampler over a full (unblocked) rating matrix — the
/// paper's "BMF" baseline and the phase-(a) reference path.
pub struct NativeGibbs {
    /// Latent dimension.
    pub k: usize,
    /// Residual noise precision (fixed, or resampled by
    /// [`NativeGibbs::sweep_with_tau_sampling`]).
    pub tau: f64,
    /// Current row-side factor sample (rows × k).
    pub u: Vec<f32>,
    /// Current column-side factor sample (cols × k).
    pub v: Vec<f32>,
    /// Global rating mean (training is mean-centred).
    pub global_mean: f64,
    r_rows: Csr,
    r_cols: Csr,
    rng: Rng,
    hyper_prior: crate::gibbs::hyper::NormalWishartPrior,
}

impl NativeGibbs {
    /// Initialize a sampler on `train` (mean-centred internally) with
    /// N(0, 0.1)-initialized factors.
    pub fn new(train: &crate::data::sparse::Coo, k: usize, tau: f64, seed: u64) -> NativeGibbs {
        let global_mean = train.mean();
        let mut centered = train.clone();
        for e in centered.entries.iter_mut() {
            e.val -= global_mean as f32;
        }
        let train = &centered;
        let r_rows = Csr::from_coo(train);
        let r_cols = r_rows.transpose();
        let mut rng = Rng::seed_from_u64(seed);
        // init factors from N(0, 0.1) like the paper's implementations
        let mut u = standard_normal_vec(&mut rng, train.rows * k);
        let mut v = standard_normal_vec(&mut rng, train.cols * k);
        for x in u.iter_mut().chain(v.iter_mut()) {
            *x *= 0.1;
        }
        NativeGibbs {
            k,
            tau,
            u,
            v,
            global_mean,
            r_rows,
            r_cols,
            rng,
            hyper_prior: crate::gibbs::hyper::NormalWishartPrior::default_for_dim(k),
        }
    }

    /// One full Gibbs sweep with τ resampled from its Gamma conditional
    /// (the BPMF extension; the paper's fixed-τ path is `sweep`).
    pub fn sweep_with_tau_sampling(&mut self, a0: f64, b0: f64) {
        self.sweep();
        // SSE of the current factor state over the training observations
        let k = self.k;
        let mut sse = 0.0f64;
        let mut n_obs = 0usize;
        for i in 0..self.r_rows.rows {
            let (cols, vals) = self.r_rows.row(i);
            for (c, r) in cols.iter().zip(vals) {
                let pred: f32 = (0..k)
                    .map(|j| self.u[i * k + j] * self.v[*c as usize * k + j])
                    .sum();
                sse += ((pred - r) as f64).powi(2);
                n_obs += 1;
            }
        }
        self.tau = crate::gibbs::hyper::sample_tau(&mut self.rng, a0, b0, sse, n_obs);
    }

    /// One full Gibbs sweep: hyperparameters, U side, V side.
    pub fn sweep(&mut self) {
        let k = self.k;
        // hyperparameters per side (Normal-Wishart conditional on factors)
        let uf: Vec<f64> = self.u.iter().map(|&x| x as f64).collect();
        let hu = crate::gibbs::hyper::sample_hyper(
            &mut self.rng,
            &self.hyper_prior,
            &uf,
            self.r_rows.rows,
            k,
        );
        let vf: Vec<f64> = self.v.iter().map(|&x| x as f64).collect();
        let hv = crate::gibbs::hyper::sample_hyper(
            &mut self.rng,
            &self.hyper_prior,
            &vf,
            self.r_cols.rows,
            k,
        );

        let prior_u = RowGaussians::broadcast(self.r_rows.rows, &hu.mu, &hu.lambda);
        let noise_u = standard_normal_vec(&mut self.rng, self.r_rows.rows * k);
        let (u_new, _) =
            sample_side_native(&self.r_rows, &self.v, k, &prior_u, self.tau, &noise_u);
        self.u = u_new;

        let prior_v = RowGaussians::broadcast(self.r_cols.rows, &hv.mu, &hv.lambda);
        let noise_v = standard_normal_vec(&mut self.rng, self.r_cols.rows * k);
        let (v_new, _) =
            sample_side_native(&self.r_cols, &self.u, k, &prior_v, self.tau, &noise_v);
        self.v = v_new;
    }

    /// RMSE of the current factor state on `test`.
    pub fn rmse(&self, test: &crate::data::sparse::Coo) -> f64 {
        let k = self.k;
        crate::metrics::rmse::rmse_with(test, |r, c| {
            self.global_mean
                + (0..k).map(|j| (self.u[r * k + j] * self.v[c * k + j]) as f64).sum::<f64>()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::data::sparse::Coo;

    #[test]
    fn posterior_concentrates_with_strong_data() {
        // one row, many observations of a known u*: conditional mean should
        // approach the least-squares solution
        let k = 3;
        let u_star = [0.5f32, -1.0, 0.25];
        let d = 500;
        let mut rng = Rng::seed_from_u64(2);
        let v: Vec<f32> = standard_normal_vec(&mut rng, d * k);
        let mut coo = Coo::new(1, d);
        for c in 0..d {
            let dot: f32 = (0..k).map(|j| u_star[j] * v[c * k + j]).sum();
            coo.push(0, c, dot); // noiseless
        }
        let csr = Csr::from_coo(&coo);
        let prior = RowGaussians::standard(1, k, 1.0);
        let noise = vec![0.0f32; k];
        let (_, mean) = sample_side_native(&csr, &v, k, &prior, 100.0, &noise);
        for j in 0..k {
            assert!((mean[j] - u_star[j]).abs() < 0.05, "mean[{j}]={}", mean[j]);
        }
    }

    #[test]
    fn zero_noise_sample_equals_mean() {
        let d = SyntheticDataset::by_name("movielens", 0.0005, 3).unwrap();
        let csr = Csr::from_coo(&d.ratings);
        let k = d.k;
        let mut rng = Rng::seed_from_u64(4);
        let v = standard_normal_vec(&mut rng, d.ratings.cols * k);
        let prior = RowGaussians::standard(csr.rows, k, 2.0);
        let noise = vec![0.0f32; csr.rows * k];
        let (s, m) = sample_side_native(&csr, &v, k, &prior, 1.5, &noise);
        for (a, b) in s.iter().zip(&m) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn chunked_rows_match_full_half_sweep_bitwise() {
        // rows are conditionally independent given v, so sampling any row
        // range in isolation (the pipelined publish unit) must reproduce
        // the full half-sweep bit for bit
        let d = SyntheticDataset::by_name("movielens", 0.0005, 13).unwrap();
        let csr = Csr::from_coo(&d.ratings);
        let k = d.k;
        let mut rng = Rng::seed_from_u64(14);
        let v = standard_normal_vec(&mut rng, d.ratings.cols * k);
        let prior = RowGaussians::standard(csr.rows, k, 1.0);
        let noise = standard_normal_vec(&mut rng, csr.rows * k);
        let (full_s, full_m) = sample_side_native(&csr, &v, k, &prior, 2.0, &noise);
        let chunk = 7;
        let mut a = 0;
        while a < csr.rows {
            let b = (a + chunk).min(csr.rows);
            let mut s = vec![0.0f32; (b - a) * k];
            let mut m = vec![0.0f32; (b - a) * k];
            sample_rows_into(&csr, a..b, &v, k, &prior, 2.0, &noise, &mut s, &mut m);
            assert_eq!(s[..], full_s[a * k..b * k], "samples of rows {a}..{b}");
            assert_eq!(m[..], full_m[a * k..b * k], "means of rows {a}..{b}");
            a = b;
        }
    }

    #[test]
    fn unobserved_row_returns_prior_mean() {
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0); // row 1 has no observations
        let csr = Csr::from_coo(&coo);
        let k = 2;
        let v = vec![0.3f32; 3 * k];
        let mut prior = RowGaussians::standard(2, k, 1.0);
        prior.mean[k] = 0.7; // row 1 prior mean
        prior.mean[k + 1] = -0.4;
        let noise = vec![0.0f32; 2 * k];
        let (s, _) = sample_side_native(&csr, &v, k, &prior, 1.0, &noise);
        assert!((s[k] - 0.7).abs() < 1e-6);
        assert!((s[k + 1] + 0.4).abs() < 1e-6);
    }

    #[test]
    fn tau_sampling_tracks_residual_precision() {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 9).unwrap();
        let (train, _) = holdout_split_covered(&d.ratings, 0.2, 10);
        let mut g = NativeGibbs::new(&train, d.k, 1.0, 11); // start far from truth
        for _ in 0..10 {
            g.sweep_with_tau_sampling(1.0, 1.0);
        }
        // residual noise in the generator is ~0.4 std on centred ratings →
        // sampled tau should move well above the 1.0 start
        assert!(g.tau > 2.0, "tau stayed at {}", g.tau);
        assert!(g.tau.is_finite());
    }

    #[test]
    fn gibbs_learns_synthetic_data() {
        // end-to-end: RMSE after a few sweeps must beat the mean predictor
        let d = SyntheticDataset::by_name("movielens", 0.0015, 5).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 6);
        let mut g = NativeGibbs::new(&train, d.k, 2.0, 7);
        let rmse0 = g.rmse(&test);
        for _ in 0..8 {
            g.sweep();
        }
        let rmse = g.rmse(&test);
        // baseline: predict the global mean
        let mean = train.mean();
        let mean_rmse = {
            let sse: f64 =
                test.entries.iter().map(|e| (e.val as f64 - mean).powi(2)).sum();
            (sse / test.nnz() as f64).sqrt()
        };
        assert!(rmse < mean_rmse, "gibbs rmse {rmse} vs mean {mean_rmse}");
        assert!(rmse < rmse0, "no improvement from sweeps: {rmse0} -> {rmse}");
    }
}
