//! Pure-rust BPMF Gibbs half-sweep — the oracle for the AOT HLO path and
//! the plain-BMF baseline sampler.
//!
//! `sample_side_native` implements EXACTLY the math of
//! python/compile/model.py::sample_side, consuming the same injected noise,
//! so the two paths can be compared bit-for-tolerance on identical inputs.
//!
//! The hot path is [`RowSampler`]: a reusable scratch arena (packed
//! precision triangle, rhs, mean, noise buffers) that samples each row
//! with zero allocations, accumulating the τ·v_d·v_dᵀ rank-1 updates in a
//! packed upper-triangle layout that a [`PackedCholesky`] then factors in
//! place. Its output is **bitwise identical** to the retained naive
//! kernel [`sample_rows_reference`] (the pre-optimization implementation,
//! kept as the equivalence oracle and the benchmark baseline) — see
//! docs/ARCHITECTURE.md §"The Gibbs kernel" for the full contract table.

use crate::data::sparse::Csr;
use crate::linalg::{Cholesky, Mat, NotPositiveDefinite, PackedCholesky};
use crate::posterior::RowGaussians;
use crate::rng::{normal::standard_normal_vec, Rng};

/// Floating-point regime of the per-row Gibbs kernel.
///
/// [`GibbsPrecision::F64`] (the default) is the reference regime every
/// bitwise-equivalence contract in the repo is stated in. With
/// [`GibbsPrecision::F32`] the per-row precision triangle, Cholesky
/// factor, and triangular solves use f32 *storage* while every inner
/// accumulation still runs in f64 — roughly half the per-row triangle
/// traffic in exchange for results that agree with F64 only to f32
/// rounding (~1e-3 relative), so it is opt-in
/// (`TrainConfig::kernel_precision`, CLI `--kernel-f32`) and excluded
/// from all bitwise contracts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GibbsPrecision {
    /// f64 storage and accumulation everywhere (default; bitwise regime).
    #[default]
    F64,
    /// f32 storage for the row's triangle/factor/solves, f64 accumulation
    /// inside every dot product. Documented-tolerance regime.
    F32,
}

/// A row's posterior precision matrix was not positive definite — e.g. a
/// degenerate propagated prior (zero precision) on a row with no
/// observations. Carries the failing row so the scheduler's failure path
/// can report which row of which block broke; surfaced to callers as a
/// `TrainOutcome::Failed`, never a panic.
#[derive(Debug, thiserror::Error)]
#[error("row {row}: posterior precision is not positive definite ({source})")]
pub struct SampleError {
    /// Index of the failing row, global to the sampled side.
    pub row: usize,
    /// The failing pivot, from the Cholesky factorization.
    #[source]
    pub source: NotPositiveDefinite,
}

/// Reusable per-row sampling arena: one allocation per *chunk*, zero per
/// row. Holds the packed precision triangle (k(k+1)/2 f64 — ~1.1 KB at
/// k = 16, L1-resident), the rhs/mean/noise vectors, and the f32 shadow
/// buffers of the [`GibbsPrecision::F32`] regime.
///
/// Construct once per shard/chunk worker and feed it row ranges; the
/// arena's contents carry no state across rows, so reuse never changes a
/// result. One conditional Gibbs row update is:
///
/// 1. load the prior's precision upper triangle into the packed buffer
///    and form `rhs = prior_prec · prior_mean`,
/// 2. accumulate `packed += τ·v_d·v_dᵀ` (upper triangle) and
///    `rhs += τ·r·v_d` over the row's CSR observations, four
///    observations per panel,
/// 3. factor the triangle in place ([`PackedCholesky`]), solve for the
///    conditional mean, solve `Lᵀε` for the draw.
pub struct RowSampler {
    k: usize,
    mode: GibbsPrecision,
    chol: PackedCholesky,
    rhs: Vec<f64>,
    mean: Vec<f64>,
    eps: Vec<f64>,
    /// f32-storage shadow of the packed triangle (F32 regime only).
    packed32: Vec<f32>,
    mean32: Vec<f32>,
    eps32: Vec<f32>,
}

impl RowSampler {
    /// Arena for latent dimension `k` in the given precision regime.
    pub fn new(k: usize, mode: GibbsPrecision) -> RowSampler {
        let (tri, kv) = if mode == GibbsPrecision::F32 { (k * (k + 1) / 2, k) } else { (0, 0) };
        RowSampler {
            k,
            mode,
            chol: PackedCholesky::new(k),
            rhs: vec![0.0; k],
            mean: vec![0.0; k],
            eps: vec![0.0; k],
            packed32: vec![0.0; tri],
            mean32: vec![0.0; kv],
            eps32: vec![0.0; kv],
        }
    }

    /// Latent dimension of the arena.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Precision regime of the arena.
    pub fn mode(&self) -> GibbsPrecision {
        self.mode
    }

    /// Update the rows in `rows` (global indices into
    /// `csr`/`prior`/`noise`), writing results into the chunk-local
    /// `samples`/`means` buffers (each `rows.len() × k`). Rows are
    /// conditionally independent given `v`, so a chunk's output is
    /// bitwise identical whether it is sampled alone (the pipelined
    /// sweep's publish unit) or as part of a full half-sweep.
    #[allow(clippy::too_many_arguments)]
    pub fn sample_rows_into(
        &mut self,
        csr: &Csr,
        rows: std::ops::Range<usize>,
        v: &[f32],
        prior: &RowGaussians,
        tau: f64,
        noise: &[f32],
        samples: &mut [f32],
        means: &mut [f32],
    ) -> Result<(), SampleError> {
        let k = self.k;
        let n = csr.rows;
        assert_eq!(prior.n, n);
        assert_eq!(prior.k, k);
        assert_eq!(noise.len(), n * k);
        assert_eq!(v.len(), csr.cols * k);
        assert!(rows.end <= n, "row range exceeds the side");
        assert_eq!(samples.len(), rows.len() * k);
        assert_eq!(means.len(), rows.len() * k);

        let row0 = rows.start;
        for i in rows {
            let packed = self.chol.packed_mut();
            // 1. prior natural parameters. The precision's upper-triangle
            //    rows land contiguously in the packed buffer (packed
            //    row-major upper == packed column-major lower — same
            //    bytes, so the factorization reads them as L-packed);
            //    rhs uses the FULL stored row like the reference's
            //    matvec, in case a stored lower mirror differs bitwise.
            let pp = &prior.prec[i * k * k..(i + 1) * k * k];
            let pm = &prior.mean[i * k..(i + 1) * k];
            let mut off = 0;
            for a in 0..k {
                packed[off..off + (k - a)].copy_from_slice(&pp[a * k + a..(a + 1) * k]);
                off += k - a;
            }
            for a in 0..k {
                let mut s = 0.0f64;
                for (x, m) in pp[a * k..(a + 1) * k].iter().zip(pm) {
                    s += x * m;
                }
                self.rhs[a] = s;
            }

            // 2. accumulate observed items over the CSR row
            let (cols, vals) = csr.row(i);
            accumulate_observations(packed, &mut self.rhs, k, cols, vals, v, tau);

            // 3. factor + solve in the regime's storage
            let local = (i - row0) * k;
            match self.mode {
                GibbsPrecision::F64 => {
                    self.chol
                        .factor_in_place()
                        .map_err(|source| SampleError { row: i, source })?;
                    self.mean.copy_from_slice(&self.rhs);
                    self.chol.solve_in_place(&mut self.mean);
                    for (e, &x) in self.eps.iter_mut().zip(&noise[i * k..(i + 1) * k]) {
                        *e = x as f64;
                    }
                    self.chol.solve_upper_in_place(&mut self.eps);
                    for j in 0..k {
                        samples[local + j] = (self.mean[j] + self.eps[j]) as f32;
                        means[local + j] = self.mean[j] as f32;
                    }
                }
                GibbsPrecision::F32 => {
                    // round the f64-accumulated triangle and rhs to f32
                    // storage once, then factor/solve with f64 inner
                    // accumulation (documented-tolerance fast path)
                    for (d, &s) in self.packed32.iter_mut().zip(self.chol.packed().iter()) {
                        *d = s as f32;
                    }
                    for (d, &s) in self.mean32.iter_mut().zip(&self.rhs) {
                        *d = s as f32;
                    }
                    factor_packed_f32(&mut self.packed32, k)
                        .map_err(|source| SampleError { row: i, source })?;
                    solve_lower_packed_f32(&self.packed32, k, &mut self.mean32);
                    solve_upper_packed_f32(&self.packed32, k, &mut self.mean32);
                    self.eps32.copy_from_slice(&noise[i * k..(i + 1) * k]);
                    solve_upper_packed_f32(&self.packed32, k, &mut self.eps32);
                    for j in 0..k {
                        samples[local + j] = self.mean32[j] + self.eps32[j];
                        means[local + j] = self.mean32[j];
                    }
                }
            }
        }
        Ok(())
    }

    /// Sample a full side (all `csr.rows` rows) into fresh buffers —
    /// the per-shard entry point of the lockstep half-sweep.
    pub fn sample_side(
        &mut self,
        csr: &Csr,
        v: &[f32],
        prior: &RowGaussians,
        tau: f64,
        noise: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>), SampleError> {
        let n = csr.rows;
        let mut samples = vec![0.0f32; n * self.k];
        let mut means = vec![0.0f32; n * self.k];
        self.sample_rows_into(csr, 0..n, v, prior, tau, noise, &mut samples, &mut means)?;
        Ok((samples, means))
    }
}

/// The kernel's inner loop: `packed += τ·v_d·v_dᵀ` (upper triangle) and
/// `rhs += τ·r·v_d` over one row's observations, four per panel. Per
/// triangle element the additions land in ascending observation order —
/// exactly the reference kernel's order — so panelling is a pure
/// register-tiling change, bitwise invisible.
#[inline]
fn accumulate_observations(
    packed: &mut [f64],
    rhs: &mut [f64],
    k: usize,
    cols: &[u32],
    vals: &[f32],
    v: &[f32],
    tau: f64,
) {
    let mut c_panels = cols.chunks_exact(4);
    let mut r_panels = vals.chunks_exact(4);
    for (cp, rp) in (&mut c_panels).zip(&mut r_panels) {
        let w0 = &v[cp[0] as usize * k..][..k];
        let w1 = &v[cp[1] as usize * k..][..k];
        let w2 = &v[cp[2] as usize * k..][..k];
        let w3 = &v[cp[3] as usize * k..][..k];
        let (r0, r1, r2, r3) = (rp[0] as f64, rp[1] as f64, rp[2] as f64, rp[3] as f64);
        let mut off = 0;
        for a in 0..k {
            let va0 = tau * w0[a] as f64;
            let va1 = tau * w1[a] as f64;
            let va2 = tau * w2[a] as f64;
            let va3 = tau * w3[a] as f64;
            let row = &mut packed[off..off + (k - a)];
            for ((((p, &b0), &b1), &b2), &b3) in
                row.iter_mut().zip(&w0[a..]).zip(&w1[a..]).zip(&w2[a..]).zip(&w3[a..])
            {
                let mut x = *p;
                x += va0 * b0 as f64;
                x += va1 * b1 as f64;
                x += va2 * b2 as f64;
                x += va3 * b3 as f64;
                *p = x;
            }
            let mut r = rhs[a];
            r += r0 * va0;
            r += r1 * va1;
            r += r2 * va2;
            r += r3 * va3;
            rhs[a] = r;
            off += k - a;
        }
    }
    for (c, r) in c_panels.remainder().iter().zip(r_panels.remainder()) {
        let vd = &v[*c as usize * k..][..k];
        let rv = *r as f64;
        let mut off = 0;
        for a in 0..k {
            let va = tau * vd[a] as f64;
            let row = &mut packed[off..off + (k - a)];
            for (p, &b) in row.iter_mut().zip(&vd[a..]) {
                *p += va * b as f64;
            }
            rhs[a] += rv * va;
            off += k - a;
        }
    }
}

/// In-place packed Cholesky with f32 storage and f64 inner accumulation —
/// the [`GibbsPrecision::F32`] regime's factorization.
fn factor_packed_f32(d: &mut [f32], k: usize) -> Result<(), NotPositiveDefinite> {
    let off = |j: usize| j * (2 * k - j + 1) / 2;
    for j in 0..k {
        for i in j..k {
            let mut s = d[off(j) + (i - j)] as f64;
            for t in 0..j {
                s -= (d[off(t) + (i - t)] as f64) * (d[off(t) + (j - t)] as f64);
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return Err(NotPositiveDefinite { pivot: s, index: j });
                }
                d[off(j)] = s.sqrt() as f32;
            } else {
                d[off(j) + (i - j)] = (s / d[off(j)] as f64) as f32;
            }
        }
    }
    Ok(())
}

/// Forward substitution (L y = b) in the f32-storage regime.
fn solve_lower_packed_f32(d: &[f32], k: usize, b: &mut [f32]) {
    let off = |j: usize| j * (2 * k - j + 1) / 2;
    for i in 0..k {
        let mut s = b[i] as f64;
        for t in 0..i {
            s -= (d[off(t) + (i - t)] as f64) * (b[t] as f64);
        }
        b[i] = (s / d[off(i)] as f64) as f32;
    }
}

/// Back substitution (Lᵀ x = b) in the f32-storage regime.
fn solve_upper_packed_f32(d: &[f32], k: usize, b: &mut [f32]) {
    let off = |j: usize| j * (2 * k - j + 1) / 2;
    for i in (0..k).rev() {
        let col = &d[off(i)..off(i) + (k - i)];
        let mut s = b[i] as f64;
        for t in (i + 1)..k {
            s -= (col[t - i] as f64) * (b[t] as f64);
        }
        b[i] = (s / col[0] as f64) as f32;
    }
}

/// One conditional Gibbs update of the N rows of one side, given the D
/// opposite-side factor rows `v` (row-major d × k, f32 like the runtime).
///
/// Returns (samples, conditional means), both row-major n × k f32, or a
/// typed [`SampleError`] naming the row whose posterior precision was not
/// positive definite (a degenerate prior — never a panic).
pub fn sample_side_native(
    csr: &Csr,
    v: &[f32],
    k: usize,
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
) -> Result<(Vec<f32>, Vec<f32>), SampleError> {
    RowSampler::new(k, GibbsPrecision::F64).sample_side(csr, v, prior, tau, noise)
}

/// The chunked core of [`sample_side_native`] as a free function: one
/// arena is built per call, so chunked callers that care about the
/// per-row allocation win should hold a [`RowSampler`] and call
/// [`RowSampler::sample_rows_into`] directly.
#[allow(clippy::too_many_arguments)]
pub fn sample_rows_into(
    csr: &Csr,
    rows: std::ops::Range<usize>,
    v: &[f32],
    k: usize,
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
    samples: &mut [f32],
    means: &mut [f32],
) -> Result<(), SampleError> {
    RowSampler::new(k, GibbsPrecision::F64)
        .sample_rows_into(csr, rows, v, prior, tau, noise, samples, means)
}

/// The pre-optimization kernel, retained verbatim as the bitwise oracle:
/// per-row dense precision matrix, allocating Cholesky, allocating
/// solves. [`RowSampler`] in the [`GibbsPrecision::F64`] regime must
/// reproduce this bit for bit (property-tested in `tests/kernel.rs`),
/// and `perf_probe`'s `p10_kernel_*` section measures the optimized
/// kernel against it.
#[allow(clippy::too_many_arguments)]
pub fn sample_rows_reference(
    csr: &Csr,
    rows: std::ops::Range<usize>,
    v: &[f32],
    k: usize,
    prior: &RowGaussians,
    tau: f64,
    noise: &[f32],
    samples: &mut [f32],
    means: &mut [f32],
) -> Result<(), SampleError> {
    let n = csr.rows;
    assert_eq!(prior.n, n);
    assert_eq!(prior.k, k);
    assert_eq!(noise.len(), n * k);
    assert_eq!(v.len(), csr.cols * k);
    assert!(rows.end <= n, "row range exceeds the side");
    assert_eq!(samples.len(), rows.len() * k);
    assert_eq!(means.len(), rows.len() * k);

    let row0 = rows.start;
    let mut prec = Mat::zeros(k, k);
    let mut rhs = vec![0.0f64; k];

    for i in rows {
        // start from the prior's natural parameters
        prec.data.copy_from_slice(&prior.prec[i * k * k..(i + 1) * k * k]);
        let pm = prior.row_mean(i);
        let prior_prec = prior.row_prec(i);
        let h = prior_prec.matvec(pm);
        rhs.copy_from_slice(&h);

        // accumulate observed items: prec += tau * v_d v_d^T, rhs += tau r v_d.
        // v_d v_d^T is symmetric — accumulate the upper triangle only and
        // mirror once per row (≈2x on the K² hot term).
        let (cols, vals) = csr.row(i);
        for (c, r) in cols.iter().zip(vals) {
            let vd = &v[*c as usize * k..(*c as usize + 1) * k];
            for a in 0..k {
                let va = tau * vd[a] as f64;
                let pa = &mut prec.data[a * k + a..(a + 1) * k];
                for (pv, &vb) in pa.iter_mut().zip(&vd[a..]) {
                    *pv += va * vb as f64;
                }
                rhs[a] += (*r as f64) * va;
            }
        }
        for a in 1..k {
            for b in 0..a {
                prec.data[a * k + b] = prec.data[b * k + a];
            }
        }

        let chol = Cholesky::new(&prec).map_err(|source| SampleError { row: i, source })?;
        let mean = chol.solve(&rhs);
        let eps: Vec<f64> = noise[i * k..(i + 1) * k].iter().map(|&x| x as f64).collect();
        let draw = chol.sample_with_precision(&mean, &eps);
        let local = (i - row0) * k;
        for j in 0..k {
            samples[local + j] = draw[j] as f32;
            means[local + j] = mean[j] as f32;
        }
    }
    Ok(())
}

/// Plain-BPMF Gibbs sampler over a full (unblocked) rating matrix — the
/// paper's "BMF" baseline and the phase-(a) reference path.
pub struct NativeGibbs {
    /// Latent dimension.
    pub k: usize,
    /// Residual noise precision (fixed, or resampled by
    /// [`NativeGibbs::sweep_with_tau_sampling`]).
    pub tau: f64,
    /// Current row-side factor sample (rows × k).
    pub u: Vec<f32>,
    /// Current column-side factor sample (cols × k).
    pub v: Vec<f32>,
    /// Global rating mean (training is mean-centred).
    pub global_mean: f64,
    r_rows: Csr,
    r_cols: Csr,
    rng: Rng,
    hyper_prior: crate::gibbs::hyper::NormalWishartPrior,
}

impl NativeGibbs {
    /// Initialize a sampler on `train` (mean-centred internally) with
    /// N(0, 0.1)-initialized factors.
    pub fn new(train: &crate::data::sparse::Coo, k: usize, tau: f64, seed: u64) -> NativeGibbs {
        let global_mean = train.mean();
        let mut centered = train.clone();
        for e in centered.entries.iter_mut() {
            e.val -= global_mean as f32;
        }
        let train = &centered;
        let r_rows = Csr::from_coo(train);
        let r_cols = r_rows.transpose();
        let mut rng = Rng::seed_from_u64(seed);
        // init factors from N(0, 0.1) like the paper's implementations
        let mut u = standard_normal_vec(&mut rng, train.rows * k);
        let mut v = standard_normal_vec(&mut rng, train.cols * k);
        for x in u.iter_mut().chain(v.iter_mut()) {
            *x *= 0.1;
        }
        NativeGibbs {
            k,
            tau,
            u,
            v,
            global_mean,
            r_rows,
            r_cols,
            rng,
            hyper_prior: crate::gibbs::hyper::NormalWishartPrior::default_for_dim(k),
        }
    }

    /// One full Gibbs sweep with τ resampled from its Gamma conditional
    /// (the BPMF extension; the paper's fixed-τ path is `sweep`).
    pub fn sweep_with_tau_sampling(&mut self, a0: f64, b0: f64) {
        self.sweep();
        // SSE of the current factor state over the training observations
        let k = self.k;
        let mut sse = 0.0f64;
        let mut n_obs = 0usize;
        for i in 0..self.r_rows.rows {
            let (cols, vals) = self.r_rows.row(i);
            for (c, r) in cols.iter().zip(vals) {
                let pred: f32 = (0..k)
                    .map(|j| self.u[i * k + j] * self.v[*c as usize * k + j])
                    .sum();
                sse += ((pred - r) as f64).powi(2);
                n_obs += 1;
            }
        }
        self.tau = crate::gibbs::hyper::sample_tau(&mut self.rng, a0, b0, sse, n_obs);
    }

    /// One full Gibbs sweep: hyperparameters, U side, V side.
    pub fn sweep(&mut self) {
        let k = self.k;
        // hyperparameters per side (Normal-Wishart conditional on factors)
        let uf: Vec<f64> = self.u.iter().map(|&x| x as f64).collect();
        let hu = crate::gibbs::hyper::sample_hyper(
            &mut self.rng,
            &self.hyper_prior,
            &uf,
            self.r_rows.rows,
            k,
        );
        let vf: Vec<f64> = self.v.iter().map(|&x| x as f64).collect();
        let hv = crate::gibbs::hyper::sample_hyper(
            &mut self.rng,
            &self.hyper_prior,
            &vf,
            self.r_cols.rows,
            k,
        );

        // a freshly hyper-sampled Normal-Wishart prior is SPD by
        // construction (the hyper sampler itself panics first on
        // non-finite factors), so a failure here is unreachable
        let prior_u = RowGaussians::broadcast(self.r_rows.rows, &hu.mu, &hu.lambda);
        let noise_u = standard_normal_vec(&mut self.rng, self.r_rows.rows * k);
        let (u_new, _) = sample_side_native(&self.r_rows, &self.v, k, &prior_u, self.tau, &noise_u)
            .expect("hyper-sampled prior is SPD");
        self.u = u_new;

        let prior_v = RowGaussians::broadcast(self.r_cols.rows, &hv.mu, &hv.lambda);
        let noise_v = standard_normal_vec(&mut self.rng, self.r_cols.rows * k);
        let (v_new, _) = sample_side_native(&self.r_cols, &self.u, k, &prior_v, self.tau, &noise_v)
            .expect("hyper-sampled prior is SPD");
        self.v = v_new;
    }

    /// RMSE of the current factor state on `test`.
    pub fn rmse(&self, test: &crate::data::sparse::Coo) -> f64 {
        let k = self.k;
        crate::metrics::rmse::rmse_with(test, |r, c| {
            self.global_mean
                + (0..k).map(|j| (self.u[r * k + j] * self.v[c * k + j]) as f64).sum::<f64>()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator::SyntheticDataset;
    use crate::data::split::holdout_split_covered;
    use crate::data::sparse::Coo;

    #[test]
    fn posterior_concentrates_with_strong_data() {
        // one row, many observations of a known u*: conditional mean should
        // approach the least-squares solution
        let k = 3;
        let u_star = [0.5f32, -1.0, 0.25];
        let d = 500;
        let mut rng = Rng::seed_from_u64(2);
        let v: Vec<f32> = standard_normal_vec(&mut rng, d * k);
        let mut coo = Coo::new(1, d);
        for c in 0..d {
            let dot: f32 = (0..k).map(|j| u_star[j] * v[c * k + j]).sum();
            coo.push(0, c, dot); // noiseless
        }
        let csr = Csr::from_coo(&coo);
        let prior = RowGaussians::standard(1, k, 1.0);
        let noise = vec![0.0f32; k];
        let (_, mean) = sample_side_native(&csr, &v, k, &prior, 100.0, &noise).unwrap();
        for j in 0..k {
            assert!((mean[j] - u_star[j]).abs() < 0.05, "mean[{j}]={}", mean[j]);
        }
    }

    #[test]
    fn zero_noise_sample_equals_mean() {
        let d = SyntheticDataset::by_name("movielens", 0.0005, 3).unwrap();
        let csr = Csr::from_coo(&d.ratings);
        let k = d.k;
        let mut rng = Rng::seed_from_u64(4);
        let v = standard_normal_vec(&mut rng, d.ratings.cols * k);
        let prior = RowGaussians::standard(csr.rows, k, 2.0);
        let noise = vec![0.0f32; csr.rows * k];
        let (s, m) = sample_side_native(&csr, &v, k, &prior, 1.5, &noise).unwrap();
        for (a, b) in s.iter().zip(&m) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn chunked_rows_match_full_half_sweep_bitwise() {
        // rows are conditionally independent given v, so sampling any row
        // range in isolation (the pipelined publish unit) must reproduce
        // the full half-sweep bit for bit
        let d = SyntheticDataset::by_name("movielens", 0.0005, 13).unwrap();
        let csr = Csr::from_coo(&d.ratings);
        let k = d.k;
        let mut rng = Rng::seed_from_u64(14);
        let v = standard_normal_vec(&mut rng, d.ratings.cols * k);
        let prior = RowGaussians::standard(csr.rows, k, 1.0);
        let noise = standard_normal_vec(&mut rng, csr.rows * k);
        let (full_s, full_m) = sample_side_native(&csr, &v, k, &prior, 2.0, &noise).unwrap();
        let chunk = 7;
        let mut a = 0;
        while a < csr.rows {
            let b = (a + chunk).min(csr.rows);
            let mut s = vec![0.0f32; (b - a) * k];
            let mut m = vec![0.0f32; (b - a) * k];
            sample_rows_into(&csr, a..b, &v, k, &prior, 2.0, &noise, &mut s, &mut m).unwrap();
            assert_eq!(s[..], full_s[a * k..b * k], "samples of rows {a}..{b}");
            assert_eq!(m[..], full_m[a * k..b * k], "means of rows {a}..{b}");
            a = b;
        }
    }

    #[test]
    fn optimized_kernel_matches_reference_bitwise() {
        // the tentpole contract: the arena/packed/panelled kernel is the
        // same function as the retained naive reference, to the last bit
        // (the full property sweep lives in tests/kernel.rs)
        let d = SyntheticDataset::by_name("movielens", 0.001, 21).unwrap();
        let csr = Csr::from_coo(&d.ratings);
        let k = d.k;
        let mut rng = Rng::seed_from_u64(22);
        let v = standard_normal_vec(&mut rng, d.ratings.cols * k);
        let prior = RowGaussians::standard(csr.rows, k, 1.0);
        let noise = standard_normal_vec(&mut rng, csr.rows * k);
        let n = csr.rows;
        let mut s_ref = vec![0.0f32; n * k];
        let mut m_ref = vec![0.0f32; n * k];
        sample_rows_reference(&csr, 0..n, &v, k, &prior, 2.5, &noise, &mut s_ref, &mut m_ref)
            .unwrap();
        let (s_opt, m_opt) = sample_side_native(&csr, &v, k, &prior, 2.5, &noise).unwrap();
        assert_eq!(s_opt, s_ref, "samples");
        assert_eq!(m_opt, m_ref, "means");
    }

    #[test]
    fn f32_mode_tracks_f64_within_tolerance() {
        let d = SyntheticDataset::by_name("movielens", 0.001, 31).unwrap();
        let csr = Csr::from_coo(&d.ratings);
        let k = d.k;
        let mut rng = Rng::seed_from_u64(32);
        let v = standard_normal_vec(&mut rng, d.ratings.cols * k);
        let prior = RowGaussians::standard(csr.rows, k, 1.0);
        let noise = standard_normal_vec(&mut rng, csr.rows * k);
        let (s64, m64) = RowSampler::new(k, GibbsPrecision::F64)
            .sample_side(&csr, &v, &prior, 2.0, &noise)
            .unwrap();
        let (s32, m32) = RowSampler::new(k, GibbsPrecision::F32)
            .sample_side(&csr, &v, &prior, 2.0, &noise)
            .unwrap();
        for i in 0..s64.len() {
            assert!(
                (s64[i] - s32[i]).abs() < 1e-3 * (1.0 + s64[i].abs()),
                "sample[{i}]: f64={} f32={}",
                s64[i],
                s32[i]
            );
            assert!((m64[i] - m32[i]).abs() < 1e-3 * (1.0 + m64[i].abs()), "mean[{i}]");
        }
    }

    #[test]
    fn degenerate_prior_returns_typed_error_with_row() {
        // row 1 has no observations and a zero-precision prior: its
        // posterior precision is the zero matrix — a typed SampleError
        // carrying the row, never a panic
        let mut coo = Coo::new(3, 2);
        coo.push(0, 0, 1.0);
        coo.push(2, 1, 2.0);
        let csr = Csr::from_coo(&coo);
        let k = 2;
        let v = vec![0.5f32; 2 * k];
        let mut prior = RowGaussians::standard(3, k, 1.0);
        for x in prior.prec[k * k..2 * k * k].iter_mut() {
            *x = 0.0; // degenerate prior on row 1
        }
        let noise = vec![0.0f32; 3 * k];
        let err = sample_side_native(&csr, &v, k, &prior, 1.0, &noise).unwrap_err();
        assert_eq!(err.row, 1);
        assert_eq!(err.source.index, 0);
        // the reference kernel reports the identical failure
        let mut s = vec![0.0f32; 3 * k];
        let mut m = vec![0.0f32; 3 * k];
        let ref_err =
            sample_rows_reference(&csr, 0..3, &v, k, &prior, 1.0, &noise, &mut s, &mut m)
                .unwrap_err();
        assert_eq!(ref_err.row, 1);
    }

    #[test]
    fn unobserved_row_returns_prior_mean() {
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0); // row 1 has no observations
        let csr = Csr::from_coo(&coo);
        let k = 2;
        let v = vec![0.3f32; 3 * k];
        let mut prior = RowGaussians::standard(2, k, 1.0);
        prior.mean[k] = 0.7; // row 1 prior mean
        prior.mean[k + 1] = -0.4;
        let noise = vec![0.0f32; 2 * k];
        let (s, _) = sample_side_native(&csr, &v, k, &prior, 1.0, &noise).unwrap();
        assert!((s[k] - 0.7).abs() < 1e-6);
        assert!((s[k + 1] + 0.4).abs() < 1e-6);
    }

    #[test]
    fn tau_sampling_tracks_residual_precision() {
        let d = SyntheticDataset::by_name("movielens", 0.0015, 9).unwrap();
        let (train, _) = holdout_split_covered(&d.ratings, 0.2, 10);
        let mut g = NativeGibbs::new(&train, d.k, 1.0, 11); // start far from truth
        for _ in 0..10 {
            g.sweep_with_tau_sampling(1.0, 1.0);
        }
        // residual noise in the generator is ~0.4 std on centred ratings →
        // sampled tau should move well above the 1.0 start
        assert!(g.tau > 2.0, "tau stayed at {}", g.tau);
        assert!(g.tau.is_finite());
    }

    #[test]
    fn gibbs_learns_synthetic_data() {
        // end-to-end: RMSE after a few sweeps must beat the mean predictor
        let d = SyntheticDataset::by_name("movielens", 0.0015, 5).unwrap();
        let (train, test) = holdout_split_covered(&d.ratings, 0.2, 6);
        let mut g = NativeGibbs::new(&train, d.k, 2.0, 7);
        let rmse0 = g.rmse(&test);
        for _ in 0..8 {
            g.sweep();
        }
        let rmse = g.rmse(&test);
        // baseline: predict the global mean
        let mean = train.mean();
        let mean_rmse = {
            let sse: f64 =
                test.entries.iter().map(|e| (e.val as f64 - mean).powi(2)).sum();
            (sse / test.nnz() as f64).sqrt()
        };
        assert!(rmse < mean_rmse, "gibbs rmse {rmse} vs mean {mean_rmse}");
        assert!(rmse < rmse0, "no improvement from sweeps: {rmse0} -> {rmse}");
    }
}
