//! Shard-store manifest: the versioned JSON index of an ingested dataset.
//!
//! An ingest directory holds one `manifest.json` plus one binary shard
//! file per grid block. The manifest records everything needed to open
//! the store without touching a shard: matrix dimensions, the block grid,
//! the centring mean, and — per shard — its block shape, entry count and
//! an FNV-1a checksum of the file bytes. [`ShardStore::open`]
//! (`store::shard`) re-derives the grid bounds from `(rows, cols, grid)`
//! with the exact arithmetic of [`crate::partition::Grid`], which is what
//! makes store-backed training bitwise-identical to the resident path.
//!
//! **Version gate:** the writer emits [`STORE_VERSION`]; the reader
//! rejects anything outside [`SUPPORTED_STORE_VERSIONS`] with a
//! [`StoreError::Version`] naming the found and supported versions —
//! the same found-vs-supported discipline as the checkpoint loaders.
//!
//! All writes (manifest and shards) are atomic: temp file in the same
//! directory, then rename — a crashed ingest never leaves a torn
//! `manifest.json` behind, at worst a stale `*.tmp` nobody reads.

use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};

/// Manifest format version written by this build's ingest.
pub const STORE_VERSION: usize = 1;

/// Oldest and newest manifest versions this build's reader accepts.
pub const SUPPORTED_STORE_VERSIONS: (usize, usize) = (1, 1);

/// File name of the manifest inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Bytes per triplet record in a shard file: `u32` local row, `u32`
/// local column, `f32` rating, all little-endian.
pub const RECORD_BYTES: u64 = 12;

/// Why a shard store could not be ingested, opened, or read.
///
/// Every variant names the offending file (or the config/store pair), so
/// a failed `submit` points straight at the bad artifact instead of
/// surfacing as a mid-run panic.
#[derive(Debug, thiserror::Error)]
pub enum StoreError {
    /// A file or directory could not be read or written.
    #[error("{}: io error: {source}", path.display())]
    Io {
        /// The file or directory the operation touched.
        path: PathBuf,
        /// The underlying I/O error.
        source: std::io::Error,
    },
    /// The manifest parsed but is not a valid store index (bad JSON,
    /// missing fields, or inconsistent shapes).
    #[error("{}: malformed store manifest: {msg}", path.display())]
    Malformed {
        /// The manifest file.
        path: PathBuf,
        /// What was wrong with it.
        msg: String,
    },
    /// The manifest was written by an unsupported format version.
    #[error(
        "unsupported shard store: found version {found}, this build reads \
         versions {oldest} through {newest}"
    )]
    Version {
        /// Version recorded in the manifest.
        found: usize,
        /// Oldest version this build reads.
        oldest: usize,
        /// Newest version this build reads.
        newest: usize,
    },
    /// A shard file named by the manifest does not exist.
    #[error("{}: shard file missing", path.display())]
    MissingShard {
        /// The absent shard file.
        path: PathBuf,
    },
    /// A shard file exists but its size disagrees with the manifest —
    /// a truncated or padded file.
    #[error(
        "{}: shard file is {found} bytes, manifest expects {expected}",
        path.display()
    )]
    SizeMismatch {
        /// The shard file.
        path: PathBuf,
        /// Bytes the manifest expects (`nnz * 12`).
        expected: u64,
        /// Bytes actually on disk.
        found: u64,
    },
    /// A shard file's bytes do not hash to the manifest's checksum —
    /// corruption between ingest and open.
    #[error(
        "{}: shard checksum mismatch (manifest {expected:#018x}, file {found:#018x})",
        path.display()
    )]
    ChecksumMismatch {
        /// The shard file.
        path: PathBuf,
        /// Checksum recorded at ingest.
        expected: u64,
        /// Checksum of the bytes on disk.
        found: u64,
    },
    /// The training config's grid does not match the grid the store was
    /// ingested with (shards are per-block; re-ingest to change the grid).
    #[error(
        "config grid {}x{} does not match the store's ingest grid {}x{} \
         (re-run `bmf-pp ingest` with the desired grid)",
        cfg.0, cfg.1, store.0, store.1
    )]
    GridMismatch {
        /// Grid requested by the training config.
        cfg: (usize, usize),
        /// Grid recorded in the manifest.
        store: (usize, usize),
    },
    /// The requested ingest grid cannot partition the matrix.
    #[error("cannot ingest a {rows}x{cols} matrix on a {gi}x{gj} grid")]
    InvalidGrid {
        /// Requested row blocks.
        gi: usize,
        /// Requested column blocks.
        gj: usize,
        /// Matrix rows.
        rows: usize,
        /// Matrix columns.
        cols: usize,
    },
}

/// FNV-1a 64-bit hash of a byte slice — the shard checksum. Hand-rolled
/// (the crate set is frozen); stable across platforms by construction.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical shard file name for block `(i, j)`.
pub fn shard_file_name(i: usize, j: usize) -> String {
    format!("shard-{i:04}-{j:04}.bin")
}

/// One shard (grid block) recorded in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardMeta {
    /// Row-block index in the grid.
    pub i: usize,
    /// Column-block index in the grid.
    pub j: usize,
    /// Rows of the block (must equal the grid's derived block shape).
    pub rows: usize,
    /// Columns of the block.
    pub cols: usize,
    /// Triplet records in the shard file.
    pub nnz: usize,
    /// FNV-1a 64 checksum of the shard file's bytes.
    pub checksum: u64,
    /// Shard file name, relative to the store directory.
    pub file: String,
}

impl ShardMeta {
    /// Exact byte size the shard file must have (`nnz * 12`).
    pub fn bytes(&self) -> u64 {
        self.nnz as u64 * RECORD_BYTES
    }
}

/// The parsed `manifest.json` of an ingested store directory.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// Rows of the full matrix.
    pub rows: usize,
    /// Columns of the full matrix.
    pub cols: usize,
    /// Ingest grid: (row blocks, column blocks).
    pub grid: (usize, usize),
    /// Total entries across all shards.
    pub nnz: usize,
    /// Global mean of the raw ratings, computed at ingest time over the
    /// entries in file order — exactly what the resident trainer's
    /// centring pass computes, persisted so a store-backed run centres
    /// with the bitwise-identical `f64` (JSON `f64` round-trips exactly
    /// through `util::json`).
    pub global_mean: f64,
    /// Monotonic append counter: 0 at initial ingest, bumped by one each
    /// time `bmf-pp ingest --append` folds a delta into the store. A
    /// checkpoint seeded from this store records the revision it saw
    /// (`PartialCheckpoint::store_revision`), which is how an incremental
    /// update detects that the store has moved past the checkpoint.
    pub revision: u64,
    /// Per-block shard records, in ingest (row-major block) order.
    pub shards: Vec<ShardMeta>,
}

impl Manifest {
    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> Json {
        let shards = Json::Arr(
            self.shards
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("i", s.i.into()),
                        ("j", s.j.into()),
                        ("rows", s.rows.into()),
                        ("cols", s.cols.into()),
                        ("nnz", s.nnz.into()),
                        // JSON numbers are f64; a u64 checksum round-trips
                        // through a string (the checkpoint seed idiom)
                        ("checksum", Json::Str(s.checksum.to_string())),
                        ("file", Json::Str(s.file.clone())),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("version", STORE_VERSION.into()),
            ("rows", self.rows.into()),
            ("cols", self.cols.into()),
            ("grid_i", self.grid.0.into()),
            ("grid_j", self.grid.1.into()),
            ("nnz", self.nnz.into()),
            ("global_mean", self.global_mean.into()),
            // u64 through a string, the checksum/seed idiom
            ("revision", Json::Str(self.revision.to_string())),
            ("shards", shards),
        ])
    }

    /// Parse and validate a manifest document. `path` only labels errors.
    pub fn from_json(root: &Json, path: &Path) -> Result<Manifest, StoreError> {
        let bad = |msg: &str| StoreError::Malformed {
            path: path.to_path_buf(),
            msg: msg.to_string(),
        };
        let field = |name: &str| root.get(name).and_then(Json::as_usize);
        let version = field("version").ok_or_else(|| bad("missing version"))?;
        let (oldest, newest) = SUPPORTED_STORE_VERSIONS;
        if version < oldest || version > newest {
            return Err(StoreError::Version { found: version, oldest, newest });
        }
        let rows = field("rows").ok_or_else(|| bad("missing rows"))?;
        let cols = field("cols").ok_or_else(|| bad("missing cols"))?;
        let gi = field("grid_i").ok_or_else(|| bad("missing grid_i"))?;
        let gj = field("grid_j").ok_or_else(|| bad("missing grid_j"))?;
        let nnz = field("nnz").ok_or_else(|| bad("missing nnz"))?;
        let global_mean = root
            .get("global_mean")
            .and_then(Json::as_f64)
            .ok_or_else(|| bad("missing global_mean"))?;
        // absent in manifests written before appends existed: those
        // stores have never been appended to, so revision 0 is exact
        let revision = match root.get("revision") {
            None => 0,
            Some(r) => r
                .as_str()
                .and_then(|s| s.parse::<u64>().ok())
                .ok_or_else(|| bad("revision is not a u64 string"))?,
        };
        let shards_json =
            root.get("shards").and_then(Json::as_arr).ok_or_else(|| bad("missing shards"))?;
        if gi == 0 || gj == 0 {
            return Err(bad("zero-sized grid"));
        }
        if shards_json.len() != gi * gj {
            return Err(bad(&format!(
                "expected {} shards for a {gi}x{gj} grid, found {}",
                gi * gj,
                shards_json.len()
            )));
        }
        let mut shards = Vec::with_capacity(shards_json.len());
        let mut seen = vec![false; gi * gj];
        let mut total = 0usize;
        for s in shards_json {
            let sfield = |name: &str| s.get(name).and_then(Json::as_usize);
            let i = sfield("i").ok_or_else(|| bad("shard missing i"))?;
            let j = sfield("j").ok_or_else(|| bad("shard missing j"))?;
            if i >= gi || j >= gj {
                return Err(bad(&format!("shard ({i},{j}) outside the {gi}x{gj} grid")));
            }
            if std::mem::replace(&mut seen[i * gj + j], true) {
                return Err(bad(&format!("duplicate shard ({i},{j})")));
            }
            let checksum = s
                .get("checksum")
                .and_then(Json::as_str)
                .and_then(|t| t.parse::<u64>().ok())
                .ok_or_else(|| bad("shard missing checksum"))?;
            let file = s
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| bad("shard missing file"))?
                .to_string();
            if file.contains(['/', '\\']) {
                return Err(bad(&format!("shard file name '{file}' escapes the store dir")));
            }
            let snnz = sfield("nnz").ok_or_else(|| bad("shard missing nnz"))?;
            total += snnz;
            shards.push(ShardMeta {
                i,
                j,
                rows: sfield("rows").ok_or_else(|| bad("shard missing rows"))?,
                cols: sfield("cols").ok_or_else(|| bad("shard missing cols"))?,
                nnz: snnz,
                checksum,
                file,
            });
        }
        if total != nnz {
            return Err(bad(&format!("shard nnz sums to {total}, manifest says {nnz}")));
        }
        Ok(Manifest { rows, cols, grid: (gi, gj), nnz, global_mean, revision, shards })
    }

    /// Load and parse `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, StoreError> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|source| StoreError::Io { path: path.clone(), source })?;
        let root = json::parse(&text).map_err(|e| StoreError::Malformed {
            path: path.clone(),
            msg: e.to_string(),
        })?;
        Manifest::from_json(&root, &path)
    }

    /// Atomically write `dir/manifest.json` (same-directory temp file +
    /// rename, the checkpoint discipline).
    pub fn save(&self, dir: &Path) -> Result<(), StoreError> {
        let path = dir.join(MANIFEST_FILE);
        atomic_write(&path, json::to_string_pretty(&self.to_json()).as_bytes())
    }
}

/// Write `bytes` to `path` atomically: a uniquely named temp file in the
/// same directory (pid + per-process counter keeps concurrent writers off
/// each other's temp files), then rename into place. Used for shards and
/// the manifest alike.
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<(), StoreError> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let err = |source| StoreError::Io { path: path.to_path_buf(), source };
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, bytes).map_err(err)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            std::fs::remove_file(&tmp).ok();
            Err(err(e))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            rows: 10,
            cols: 8,
            grid: (2, 1),
            nnz: 7,
            global_mean: 3.25,
            revision: u64::MAX - 5,
            shards: vec![
                ShardMeta {
                    i: 0,
                    j: 0,
                    rows: 5,
                    cols: 8,
                    nnz: 4,
                    checksum: u64::MAX - 3,
                    file: shard_file_name(0, 0),
                },
                ShardMeta {
                    i: 1,
                    j: 0,
                    rows: 5,
                    cols: 8,
                    nnz: 3,
                    checksum: 17,
                    file: shard_file_name(1, 0),
                },
            ],
        }
    }

    #[test]
    fn manifest_roundtrips_exactly() {
        let m = sample();
        let text = json::to_string_pretty(&m.to_json());
        let back =
            Manifest::from_json(&json::parse(&text).unwrap(), Path::new("m.json")).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn global_mean_roundtrips_bitwise() {
        let mut m = sample();
        m.global_mean = 3.578_912_340_000_001_2_f64;
        let text = json::to_string(&m.to_json());
        let back =
            Manifest::from_json(&json::parse(&text).unwrap(), Path::new("m.json")).unwrap();
        assert_eq!(back.global_mean.to_bits(), m.global_mean.to_bits());
    }

    #[test]
    fn legacy_manifest_without_revision_loads_as_revision_zero() {
        let mut j = sample().to_json();
        if let Json::Obj(map) = &mut j {
            map.remove("revision");
        }
        let back = Manifest::from_json(&j, Path::new("m.json")).unwrap();
        assert_eq!(back.revision, 0);
    }

    #[test]
    fn future_version_rejected_naming_supported_range() {
        let mut j = sample().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("version".into(), Json::Num(9.0));
        }
        let err = Manifest::from_json(&j, Path::new("m.json")).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, StoreError::Version { found: 9, .. }), "{msg}");
        assert!(msg.contains("found version 9"), "{msg}");
        assert!(msg.contains("versions 1 through 1"), "{msg}");
    }

    #[test]
    fn shard_count_and_nnz_consistency_enforced() {
        let mut m = sample();
        m.shards.pop();
        let j = m.to_json();
        assert!(matches!(
            Manifest::from_json(&j, Path::new("m.json")),
            Err(StoreError::Malformed { .. })
        ));

        let mut m = sample();
        m.nnz = 99;
        let j = m.to_json();
        let err = Manifest::from_json(&j, Path::new("m.json")).unwrap_err();
        assert!(err.to_string().contains("sums to 7"), "{err}");
    }

    #[test]
    fn shard_file_names_may_not_escape_the_dir() {
        let mut m = sample();
        m.shards[0].file = "../evil.bin".into();
        let j = m.to_json();
        let err = Manifest::from_json(&j, Path::new("m.json")).unwrap_err();
        assert!(err.to_string().contains("escapes"), "{err}");
    }

    #[test]
    fn fnv_is_stable() {
        // reference vectors for the 64-bit FNV-1a parameters
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }

    #[test]
    fn atomic_write_leaves_no_tmp_behind() {
        let dir = std::env::temp_dir().join(format!("bmfpp_store_aw_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("file.bin");
        atomic_write(&path, b"hello").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"hello");
        let tmps = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok()?.file_name().into_string().ok())
            .filter(|n| n.ends_with(".tmp"))
            .count();
        assert_eq!(tmps, 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
