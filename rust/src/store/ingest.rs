//! One-pass ingest: split a loaded dataset into per-block shard files.
//!
//! [`ingest`] takes the `Coo` the existing loader path produced, splits
//! it with [`Grid::split`] — the *same* single-pass router the resident
//! trainer uses, so block membership, entry order, and local coordinates
//! are identical by construction — and writes one binary shard file per
//! block plus a versioned [`Manifest`](super::Manifest). Entries are
//! written **raw** (uncentred); the global mean is computed here with the
//! same `Coo::mean` pass the resident trainer's centring uses and
//! persisted in the manifest, so materialization can centre each block
//! bitwise-identically (see `store::shard` for the full contract).
//!
//! Every file write is atomic (same-directory temp + rename), so a
//! crashed ingest never leaves a torn shard or manifest behind.

use super::manifest::{atomic_write, fnv1a64, shard_file_name, Manifest, ShardMeta, StoreError};
use super::shard::encode_block;
use crate::data::sparse::Coo;
use crate::partition::grid::{BlockId, Grid};
use std::path::{Path, PathBuf};

/// Summary of a completed ingest, for CLI reporting.
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Shard files written (`grid.0 * grid.1`).
    pub blocks: usize,
    /// Total ratings ingested.
    pub nnz: usize,
    /// Total shard bytes written (excluding the manifest).
    pub bytes: u64,
    /// Global mean persisted in the manifest.
    pub global_mean: f64,
    /// Path of the written `manifest.json`.
    pub manifest_path: PathBuf,
}

/// Split `data` on a `(gi, gj)` block grid and write shards + manifest
/// into `dir` (created if absent).
///
/// One pass over the data: `Coo::mean` for the centring constant, one
/// `Grid::split`, one encode + checksum + atomic write per block.
/// Re-ingesting into the same directory atomically replaces each file,
/// and the same input always produces byte-identical shards.
pub fn ingest(data: &Coo, gi: usize, gj: usize, dir: &Path) -> Result<IngestReport, StoreError> {
    if gi == 0 || gj == 0 || gi > data.rows || gj > data.cols {
        return Err(StoreError::InvalidGrid { gi, gj, rows: data.rows, cols: data.cols });
    }
    std::fs::create_dir_all(dir)
        .map_err(|source| StoreError::Io { path: dir.to_path_buf(), source })?;
    // Same mean the resident trainer's `center()` computes on this data.
    let global_mean = data.mean();
    let grid = Grid::new(data.rows, data.cols, gi, gj);
    let blocks = grid.split(data);
    let mut shards = Vec::with_capacity(gi * gj);
    let mut bytes_total = 0u64;
    for (i, row) in blocks.iter().enumerate() {
        for (j, block) in row.iter().enumerate() {
            let bytes = encode_block(block);
            let file = shard_file_name(i, j);
            atomic_write(&dir.join(&file), &bytes)?;
            let (rows, cols) = grid.block_shape(BlockId { i, j });
            bytes_total += bytes.len() as u64;
            shards.push(ShardMeta {
                i,
                j,
                rows,
                cols,
                nnz: block.nnz(),
                checksum: fnv1a64(&bytes),
                file,
            });
        }
    }
    let manifest = Manifest {
        rows: data.rows,
        cols: data.cols,
        grid: (gi, gj),
        nnz: data.nnz(),
        global_mean,
        revision: 0,
        shards,
    };
    manifest.save(dir)?;
    Ok(IngestReport {
        blocks: gi * gj,
        nnz: data.nnz(),
        bytes: bytes_total,
        global_mean,
        manifest_path: dir.join(super::manifest::MANIFEST_FILE),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ShardStore;

    fn toy() -> Coo {
        let mut c = Coo::new(6, 5);
        for (r, col, v) in
            [(0, 0, 1.0), (1, 3, 2.5), (2, 2, -0.5), (3, 4, 4.0), (5, 1, 3.0), (5, 4, 0.25)]
        {
            c.push(r, col, v as f32);
        }
        c
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("bmfpp_store_ingest_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn ingest_then_open_round_trips_centred_blocks() {
        let data = toy();
        let dir = temp_dir("roundtrip");
        let report = ingest(&data, 2, 2, &dir).unwrap();
        assert_eq!(report.blocks, 4);
        assert_eq!(report.nnz, data.nnz());
        let store = ShardStore::open(&dir).unwrap();
        assert_eq!(store.global_mean().to_bits(), data.mean().to_bits());

        // reference: resident path centres first, then splits
        let mean = data.mean() as f32;
        let mut centred = data.clone();
        for e in &mut centred.entries {
            e.val -= mean;
        }
        let expect = Grid::new(6, 5, 2, 2).split(&centred);
        for i in 0..2 {
            for j in 0..2 {
                let got = store.read_block(i, j).unwrap();
                assert_eq!(got.coo.entries, expect[i][j].entries, "block ({i},{j})");
                assert_eq!((got.coo.rows, got.coo.cols), (expect[i][j].rows, expect[i][j].cols));
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ingest_is_deterministic_byte_for_byte() {
        let data = toy();
        let (d1, d2) = (temp_dir("det1"), temp_dir("det2"));
        ingest(&data, 3, 2, &d1).unwrap();
        ingest(&data, 3, 2, &d2).unwrap();
        for entry in std::fs::read_dir(&d1).unwrap() {
            let name = entry.unwrap().file_name();
            assert_eq!(
                std::fs::read(d1.join(&name)).unwrap(),
                std::fs::read(d2.join(&name)).unwrap(),
                "{name:?} differs between identical ingests"
            );
        }
        std::fs::remove_dir_all(&d1).ok();
        std::fs::remove_dir_all(&d2).ok();
    }

    #[test]
    fn bad_grid_is_a_typed_error() {
        let data = toy();
        let dir = temp_dir("badgrid");
        assert!(matches!(
            ingest(&data, 0, 1, &dir),
            Err(StoreError::InvalidGrid { .. })
        ));
        assert!(matches!(
            ingest(&data, 7, 1, &dir),
            Err(StoreError::InvalidGrid { .. })
        ));
    }

    #[test]
    fn truncated_shard_and_stale_version_fail_open_typed() {
        let data = toy();
        let dir = temp_dir("corrupt");
        ingest(&data, 2, 2, &dir).unwrap();

        // truncate one shard → SizeMismatch
        let shard = dir.join(shard_file_name(0, 0));
        let bytes = std::fs::read(&shard).unwrap();
        std::fs::write(&shard, &bytes[..bytes.len() - 1]).unwrap();
        assert!(matches!(ShardStore::open(&dir), Err(StoreError::SizeMismatch { .. })));

        // flip one byte (same length) → ChecksumMismatch
        let mut flipped = bytes.clone();
        flipped[0] ^= 0xff;
        std::fs::write(&shard, &flipped).unwrap();
        assert!(matches!(ShardStore::open(&dir), Err(StoreError::ChecksumMismatch { .. })));

        // remove it → MissingShard
        std::fs::remove_file(&shard).unwrap();
        assert!(matches!(ShardStore::open(&dir), Err(StoreError::MissingShard { .. })));
        std::fs::write(&shard, &bytes).unwrap();

        // bump the manifest version → Version, naming the supported range
        let mpath = dir.join(super::super::manifest::MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        std::fs::write(&mpath, text.replacen("\"version\": 1", "\"version\": 99", 1)).unwrap();
        let err = ShardStore::open(&dir).unwrap_err();
        assert!(matches!(err, StoreError::Version { found: 99, .. }), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
