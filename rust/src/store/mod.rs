//! Out-of-core storage layer: per-block shard files on disk, an LRU
//! cache bounding residency, and DAG-aware prefetch.
//!
//! This is the subsystem that lets a training run work on datasets
//! bigger than RAM. The lifecycle:
//!
//! 1. **Ingest** ([`ingest`]): split a loaded dataset once into one
//!    binary shard file per grid block plus a versioned, checksummed
//!    [`Manifest`] — all writes atomic (tmp + rename).
//! 2. **Open** ([`ShardStore::open`]): parse + version-gate the
//!    manifest and verify every shard (existence, size, checksum) so
//!    corruption is a typed [`StoreError`] at submit time.
//! 3. **Train**: block tasks fetch their shard through a byte-budgeted
//!    [`ShardCache`]; the [`Prefetcher`] warms upcoming shards in the
//!    DAG scheduler's ready-order; hit/miss/evict/bytes counters flow
//!    into `RunStats`, `TrainEvent::ShardLoaded`, and `bmf-pp jobs`.
//!
//! The centring mean is persisted at ingest and applied per entry at
//! materialization, so a store-backed run is **bitwise-identical** to a
//! resident run of the same data, grid, and seed (see `store::shard` for
//! the full equivalence argument).

pub mod cache;
pub mod ingest;
pub mod manifest;
pub mod shard;

pub use cache::{
    LoadHook, PrefetchHandle, Prefetcher, ShardCache, ShardCounterSnapshot, ShardCounters,
    ShardLoad,
};
pub use ingest::{ingest, IngestReport};
pub use manifest::{Manifest, ShardMeta, StoreError, STORE_VERSION, SUPPORTED_STORE_VERSIONS};
pub use shard::{BlockShard, ShardStore};
