//! Byte-budgeted LRU shard cache and the DAG-fed background prefetcher.
//!
//! [`ShardCache`] sits between block tasks and a [`ShardStore`]: a task
//! asks for block `(i, j)` and gets an `Arc<BlockData>` — from memory if
//! the shard is resident (a **hit**), otherwise read + decoded from disk
//! (a **miss**). Residency is bounded by a byte budget: after every load
//! the least-recently-used shards are evicted until the total is back
//! under `cache_bytes` (0 = unbounded). Eviction only drops the cache's
//! own `Arc`; a task mid-sample keeps its block alive, so even a budget
//! smaller than one shard is safe — it just evicts every block after use.
//!
//! Loads happen **outside** the cache lock: a loading slot is marked
//! `Loading`, concurrent requesters for the same shard wait on a condvar
//! instead of reading the file twice, and everyone else proceeds.
//!
//! [`Prefetcher`] is a single background thread fed by the DAG
//! scheduler's ready-order (see `DagRunOpts::on_ready`): as the scheduler
//! unlocks a block it pushes the coordinates here, so the shard is
//! already warming from disk while the block sits in the ready queue. A
//! task whose shard was first brought in by the prefetcher counts a
//! **prefetch hit** on first touch. Prefetch I/O errors are swallowed —
//! the same typed error resurfaces on the task's own `get`.
//!
//! Counter semantics (all cumulative per cache, surfaced in `RunStats`,
//! `TrainEvent::ShardLoaded`, `bmf-pp jobs`, and `perf_probe`):
//! - `hits` — task `get`s served without this task reading disk
//!   (including waits on a load already in flight);
//! - `misses` — task `get`s that had to read the shard from disk;
//! - `prefetch_hits` — subset of hits whose shard was resident (or in
//!   flight) because of the prefetcher, counted once per load;
//! - `evictions` — shards dropped to respect the budget;
//! - `resident_bytes` / `peak_bytes` — current and high-water shard
//!   bytes resident (accounted at on-disk size, `nnz * 12`).

use super::manifest::StoreError;
use super::shard::ShardStore;
use crate::coordinator::backend::BlockData;
use std::collections::hash_map::Entry;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Cumulative cache counters, shared between the cache, the run's
/// `RunStats`, and live `jobs` snapshots. See the module docs for exact
/// semantics.
#[derive(Debug, Default)]
pub struct ShardCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    prefetch_hits: AtomicU64,
    evictions: AtomicU64,
    resident_bytes: AtomicU64,
    peak_bytes: AtomicU64,
}

/// A point-in-time copy of [`ShardCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCounterSnapshot {
    /// Task fetches served from memory.
    pub hits: u64,
    /// Task fetches that read the shard from disk.
    pub misses: u64,
    /// Hits attributable to the prefetcher (once per prefetched load).
    pub prefetch_hits: u64,
    /// Shards evicted to respect the byte budget.
    pub evictions: u64,
    /// Shard bytes currently resident.
    pub resident_bytes: u64,
    /// High-water mark of resident shard bytes.
    pub peak_bytes: u64,
}

impl ShardCounters {
    /// Copy the current counter values.
    pub fn snapshot(&self) -> ShardCounterSnapshot {
        ShardCounterSnapshot {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            prefetch_hits: self.prefetch_hits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            resident_bytes: self.resident_bytes.load(Ordering::Relaxed),
            peak_bytes: self.peak_bytes.load(Ordering::Relaxed),
        }
    }
}

/// What a disk load looked like, passed to the cache's `on_load` hook
/// (the trainer turns this into `TrainEvent::ShardLoaded`).
#[derive(Debug, Clone, Copy)]
pub struct ShardLoad {
    /// Row-block index of the loaded shard.
    pub i: usize,
    /// Column-block index of the loaded shard.
    pub j: usize,
    /// On-disk bytes of the shard.
    pub bytes: u64,
    /// Whether the prefetcher (rather than a blocked task) loaded it.
    pub prefetch: bool,
    /// Counter values just after this load was accounted.
    pub counters: ShardCounterSnapshot,
}

/// Callback invoked (outside the cache lock) after every disk load.
pub type LoadHook = Box<dyn Fn(&ShardLoad) + Send + Sync>;

enum Slot {
    /// Some thread is reading this shard from disk; wait on the condvar.
    Loading,
    /// Resident, ready to hand out.
    Ready { data: Arc<BlockData>, bytes: u64, last_used: u64, prefetched: bool },
}

struct CacheState {
    slots: HashMap<(usize, usize), Slot>,
    bytes: u64,
    tick: u64,
}

/// Byte-budgeted LRU cache over a [`ShardStore`]. Thread-safe; clone the
/// `Arc<ShardCache>` into every consumer.
pub struct ShardCache {
    store: Arc<ShardStore>,
    budget: u64,
    counters: Arc<ShardCounters>,
    state: Mutex<CacheState>,
    cv: Condvar,
    on_load: Option<LoadHook>,
}

impl ShardCache {
    /// Create a cache over `store` holding at most `budget_bytes` of
    /// shards (0 = unbounded). `counters` is shared so the run can
    /// snapshot live values; `on_load` fires after each disk load.
    pub fn new(
        store: Arc<ShardStore>,
        budget_bytes: u64,
        counters: Arc<ShardCounters>,
        on_load: Option<LoadHook>,
    ) -> ShardCache {
        ShardCache {
            store,
            budget: budget_bytes,
            counters,
            state: Mutex::new(CacheState { slots: HashMap::new(), bytes: 0, tick: 0 }),
            cv: Condvar::new(),
            on_load,
        }
    }

    /// The store this cache reads from.
    pub fn store(&self) -> &Arc<ShardStore> {
        &self.store
    }

    /// The configured byte budget (0 = unbounded).
    pub fn budget_bytes(&self) -> u64 {
        self.budget
    }

    /// The shared counters.
    pub fn counters(&self) -> &Arc<ShardCounters> {
        &self.counters
    }

    /// Fetch block `(i, j)` for a task, reading it from disk on a miss.
    /// Concurrent requests for the same shard perform one read.
    pub fn get(&self, i: usize, j: usize) -> Result<Arc<BlockData>, StoreError> {
        let key = (i, j);
        let mut g = self.state.lock().unwrap();
        loop {
            g.tick += 1;
            let tick = g.tick;
            match g.slots.entry(key) {
                Entry::Vacant(slot) => {
                    slot.insert(Slot::Loading);
                    break;
                }
                Entry::Occupied(mut slot) => match slot.get_mut() {
                    Slot::Ready { data, last_used, prefetched, .. } => {
                        *last_used = tick;
                        let first_prefetched_touch = std::mem::replace(prefetched, false);
                        let data = data.clone();
                        self.counters.hits.fetch_add(1, Ordering::Relaxed);
                        if first_prefetched_touch {
                            self.counters.prefetch_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        return Ok(data);
                    }
                    Slot::Loading => {}
                },
            }
            g = self.cv.wait(g).unwrap();
        }
        drop(g);
        // read + decode outside the lock; other shards stay available
        let loaded = self.load_block(i, j);
        let mut g = self.state.lock().unwrap();
        match loaded {
            Err(e) => {
                g.slots.remove(&key);
                self.cv.notify_all();
                Err(e)
            }
            Ok((data, bytes)) => {
                self.counters.misses.fetch_add(1, Ordering::Relaxed);
                g.tick += 1;
                let tick = g.tick;
                g.slots.insert(
                    key,
                    Slot::Ready { data: data.clone(), bytes, last_used: tick, prefetched: false },
                );
                g.bytes += bytes;
                self.evict_to_budget(&mut g);
                self.cv.notify_all();
                drop(g);
                self.fire_on_load(i, j, bytes, false);
                Ok(data)
            }
        }
    }

    /// Warm block `(i, j)` in the background. No-op if it is already
    /// resident or in flight; errors are swallowed (they resurface,
    /// typed, when a task `get`s the shard).
    pub fn prefetch(&self, i: usize, j: usize) {
        let key = (i, j);
        {
            let mut g = self.state.lock().unwrap();
            match g.slots.entry(key) {
                Entry::Occupied(_) => return,
                Entry::Vacant(slot) => {
                    slot.insert(Slot::Loading);
                }
            }
        }
        match self.load_block(i, j) {
            Err(_) => {
                let mut g = self.state.lock().unwrap();
                g.slots.remove(&key);
                self.cv.notify_all();
            }
            Ok((data, bytes)) => {
                let mut g = self.state.lock().unwrap();
                g.tick += 1;
                let tick = g.tick;
                g.slots
                    .insert(key, Slot::Ready { data, bytes, last_used: tick, prefetched: true });
                g.bytes += bytes;
                self.evict_to_budget(&mut g);
                self.cv.notify_all();
                drop(g);
                self.fire_on_load(i, j, bytes, true);
            }
        }
    }

    fn load_block(&self, i: usize, j: usize) -> Result<(Arc<BlockData>, u64), StoreError> {
        let shard = self.store.read_block(i, j)?;
        let bytes = self.store.shard_bytes(i, j);
        Ok((Arc::new(BlockData::new(shard.coo)), bytes))
    }

    /// Evict least-recently-used Ready shards until under budget (the
    /// just-inserted shard may be the victim — its requester already
    /// holds an `Arc`, so a degenerate budget still makes progress).
    fn evict_to_budget(&self, state: &mut CacheState) {
        if self.budget > 0 {
            while state.bytes > self.budget {
                let victim = state
                    .slots
                    .iter()
                    .filter_map(|(k, s)| match s {
                        Slot::Ready { last_used, .. } => Some((*last_used, *k)),
                        Slot::Loading => None,
                    })
                    .min();
                let Some((_, k)) = victim else { break };
                if let Some(Slot::Ready { bytes, .. }) = state.slots.remove(&k) {
                    state.bytes -= bytes;
                    self.counters.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.counters.resident_bytes.store(state.bytes, Ordering::Relaxed);
        self.counters.peak_bytes.fetch_max(state.bytes, Ordering::Relaxed);
    }

    fn fire_on_load(&self, i: usize, j: usize, bytes: u64, prefetch: bool) {
        if let Some(hook) = &self.on_load {
            hook(&ShardLoad { i, j, bytes, prefetch, counters: self.counters.snapshot() });
        }
    }
}

struct QueueInner {
    pending: VecDeque<(usize, usize)>,
    closed: bool,
}

struct PrefetchQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

/// Cheap cloneable handle for pushing prefetch requests from scheduler
/// callbacks.
#[derive(Clone)]
pub struct PrefetchHandle {
    queue: Arc<PrefetchQueue>,
}

impl PrefetchHandle {
    /// Ask the prefetcher to warm block `(i, j)` soon. Duplicate pending
    /// requests are coalesced; requests after shutdown are dropped.
    pub fn request(&self, i: usize, j: usize) {
        let mut g = self.queue.inner.lock().unwrap();
        if !g.closed && !g.pending.contains(&(i, j)) {
            g.pending.push_back((i, j));
            self.queue.cv.notify_one();
        }
    }
}

/// A background thread that warms shards in DAG ready-order. Dropping it
/// closes the queue and joins the thread.
pub struct Prefetcher {
    queue: Arc<PrefetchQueue>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn the prefetch thread over `cache`.
    pub fn spawn(cache: Arc<ShardCache>) -> Prefetcher {
        let queue = Arc::new(PrefetchQueue {
            inner: Mutex::new(QueueInner { pending: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        });
        let q = queue.clone();
        let worker = std::thread::Builder::new()
            .name("bmfpp-prefetch".into())
            .spawn(move || loop {
                let next = {
                    let mut g = q.inner.lock().unwrap();
                    loop {
                        if let Some(key) = g.pending.pop_front() {
                            break Some(key);
                        }
                        if g.closed {
                            break None;
                        }
                        g = q.cv.wait(g).unwrap();
                    }
                };
                match next {
                    Some((i, j)) => cache.prefetch(i, j),
                    None => return,
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher { queue, worker: Some(worker) }
    }

    /// A handle for feeding requests (e.g. from `DagRunOpts::on_ready`).
    pub fn handle(&self) -> PrefetchHandle {
        PrefetchHandle { queue: self.queue.clone() }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        {
            let mut g = self.queue.inner.lock().unwrap();
            g.closed = true;
        }
        self.queue.cv.notify_all();
        if let Some(worker) = self.worker.take() {
            worker.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse::Coo;
    use crate::store::ingest::ingest;
    use std::path::PathBuf;

    fn toy() -> Coo {
        let mut c = Coo::new(6, 6);
        for r in 0..6 {
            for j in 0..6 {
                if (r + j) % 2 == 0 {
                    c.push(r, j, (r * 6 + j) as f32 * 0.5 - 3.0);
                }
            }
        }
        c
    }

    fn open_store(tag: &str) -> (Arc<ShardStore>, PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("bmfpp_store_cache_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ingest(&toy(), 2, 2, &dir).unwrap();
        (Arc::new(ShardStore::open(&dir).unwrap()), dir)
    }

    #[test]
    fn hits_and_misses_are_counted() {
        let (store, dir) = open_store("hits");
        let counters = Arc::new(ShardCounters::default());
        let cache = ShardCache::new(store, 0, counters.clone(), None);
        cache.get(0, 0).unwrap();
        cache.get(0, 0).unwrap();
        cache.get(1, 1).unwrap();
        let snap = counters.snapshot();
        assert_eq!((snap.hits, snap.misses, snap.evictions), (1, 2, 0));
        assert!(snap.resident_bytes > 0);
        assert_eq!(snap.peak_bytes, snap.resident_bytes);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_forces_lru_eviction() {
        let (store, dir) = open_store("lru");
        let one_shard = store.shard_bytes(0, 0);
        let counters = Arc::new(ShardCounters::default());
        // budget of one shard: every new load evicts the previous one
        let cache = ShardCache::new(store, one_shard, counters.clone(), None);
        cache.get(0, 0).unwrap();
        cache.get(0, 1).unwrap();
        cache.get(0, 0).unwrap(); // evicted above, so this is a miss again
        let snap = counters.snapshot();
        assert_eq!(snap.hits, 0);
        assert_eq!(snap.misses, 3);
        assert!(snap.evictions >= 2, "evictions = {}", snap.evictions);
        assert!(snap.resident_bytes <= one_shard);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefetch_then_get_counts_a_prefetch_hit() {
        let (store, dir) = open_store("prefetch");
        let counters = Arc::new(ShardCounters::default());
        let loads = Arc::new(AtomicU64::new(0));
        let l = loads.clone();
        let hook: LoadHook = Box::new(move |info| {
            assert_eq!((info.i, info.j), (1, 0));
            l.fetch_add(1, Ordering::Relaxed);
        });
        let cache = ShardCache::new(store, 0, counters.clone(), Some(hook));
        cache.prefetch(1, 0);
        cache.prefetch(1, 0); // coalesced: already resident
        cache.get(1, 0).unwrap();
        cache.get(1, 0).unwrap(); // plain hit, prefetch credited once
        let snap = counters.snapshot();
        assert_eq!(snap.misses, 0);
        assert_eq!(snap.hits, 2);
        assert_eq!(snap.prefetch_hits, 1);
        assert_eq!(loads.load(Ordering::Relaxed), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn prefetcher_thread_warms_and_shuts_down() {
        let (store, dir) = open_store("thread");
        let counters = Arc::new(ShardCounters::default());
        let cache = Arc::new(ShardCache::new(store, 0, counters.clone(), None));
        let pf = Prefetcher::spawn(cache.clone());
        let handle = pf.handle();
        handle.request(0, 0);
        handle.request(1, 1);
        // wait until both shards are resident (Ready, not just in flight)
        for _ in 0..2500 {
            let ready = {
                let g = cache.state.lock().unwrap();
                [(0, 0), (1, 1)]
                    .iter()
                    .all(|k| matches!(g.slots.get(k), Some(Slot::Ready { .. })))
            };
            if ready {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        cache.get(0, 0).unwrap();
        drop(pf); // joins cleanly
        let snap = counters.snapshot();
        assert_eq!(snap.misses, 0, "prefetcher should have loaded both shards");
        assert_eq!(snap.prefetch_hits, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
