//! On-demand shard access: open a validated store, materialize blocks.
//!
//! [`ShardStore::open`] front-loads every integrity check — manifest
//! version gate, grid-consistency against freshly derived
//! [`Grid`] bounds, per-shard existence, size, and checksum — so that a
//! corrupt or stale store surfaces as a typed [`StoreError`] at
//! `Engine::submit` time, never as a panic in the middle of a run.
//!
//! [`ShardStore::read_block`] then reads one shard file (buffered read;
//! the container toolchain has no mmap crate, and a shard-at-a-time read
//! keeps residency bounded just the same) and decodes it into a
//! [`BlockShard`] holding the *centred* block `Coo`.
//!
//! # Bitwise-equivalence contract
//!
//! The resident path computes `center(train)` (subtract
//! `global_mean as f32` from every entry) and then `grid.split(&train)`.
//! Ingest runs `grid.split` on the *raw* entries — split routing depends
//! only on coordinates, so block membership, order, and local coordinates
//! are identical — and this module subtracts the manifest's
//! `global_mean as f32` per entry at materialization. Subtraction is a
//! per-entry operation, so doing it after the split instead of before
//! yields bit-for-bit the same `f32` values. The resulting `Coo` is
//! therefore bitwise-equal to the slice the resident partitioner would
//! have produced, which is what makes store-backed training
//! bitwise-identical to resident training.

use super::manifest::{fnv1a64, Manifest, ShardMeta, StoreError, RECORD_BYTES};
use crate::data::sparse::{Coo, Entry};
use crate::partition::grid::{BlockId, Grid};
use std::path::{Path, PathBuf};

/// One grid block materialized from its shard file: the centred `Coo`
/// slice, bitwise-equal to what `grid.split(&centred_train)[i][j]` would
/// have produced in a resident run.
#[derive(Debug, Clone)]
pub struct BlockShard {
    /// Row-block index.
    pub i: usize,
    /// Column-block index.
    pub j: usize,
    /// The centred block data in block-local coordinates.
    pub coo: Coo,
}

/// A validated, openable shard store directory.
///
/// Open once (all integrity checks run eagerly), then `read_block` as
/// many times as the cache asks; reads are independent and thread-safe
/// (`&self`, no interior state).
#[derive(Debug)]
pub struct ShardStore {
    dir: PathBuf,
    manifest: Manifest,
    grid: Grid,
}

impl ShardStore {
    /// Open `dir`, parse + version-gate its manifest, and verify every
    /// shard file (existence, exact size, checksum) before returning.
    ///
    /// This reads each shard once, one at a time — open cost is a full
    /// sequential pass over the dataset, but peak residency stays one
    /// shard. All failures are typed [`StoreError`]s naming the file.
    pub fn open(dir: &Path) -> Result<ShardStore, StoreError> {
        let manifest = Manifest::load(dir)?;
        let manifest_path = dir.join(super::manifest::MANIFEST_FILE);
        let (gi, gj) = manifest.grid;
        if gi > manifest.rows || gj > manifest.cols {
            return Err(StoreError::Malformed {
                path: manifest_path,
                msg: format!(
                    "grid {gi}x{gj} exceeds matrix {}x{}",
                    manifest.rows, manifest.cols
                ),
            });
        }
        // Re-derive the partition bounds with the same arithmetic the
        // resident trainer uses; every shard's recorded shape must match.
        let grid = Grid::new(manifest.rows, manifest.cols, gi, gj);
        for s in &manifest.shards {
            let (rows, cols) = grid.block_shape(BlockId { i: s.i, j: s.j });
            if (s.rows, s.cols) != (rows, cols) {
                return Err(StoreError::Malformed {
                    path: manifest_path,
                    msg: format!(
                        "shard ({},{}) is {}x{}, grid derives {rows}x{cols}",
                        s.i, s.j, s.rows, s.cols
                    ),
                });
            }
            verify_shard_file(dir, s)?;
        }
        Ok(ShardStore { dir: dir.to_path_buf(), manifest, grid })
    }

    /// Rows of the full matrix.
    pub fn rows(&self) -> usize {
        self.manifest.rows
    }

    /// Columns of the full matrix.
    pub fn cols(&self) -> usize {
        self.manifest.cols
    }

    /// Total ratings across all shards.
    pub fn nnz(&self) -> usize {
        self.manifest.nnz
    }

    /// The ingest grid `(row_blocks, col_blocks)` — training must use
    /// exactly this grid (shards are per-block).
    pub fn grid_dims(&self) -> (usize, usize) {
        self.manifest.grid
    }

    /// Global mean of the raw ratings, persisted at ingest; training
    /// centres with this exact `f64` (bitwise-equal to the resident
    /// `center()` pass over the same data).
    pub fn global_mean(&self) -> f64 {
        self.manifest.global_mean
    }

    /// The store's append revision: 0 at initial ingest, +1 per
    /// `ingest --append`. Checkpoints seeded from this store record the
    /// revision they trained against (see
    /// [`Manifest::revision`](super::Manifest)).
    pub fn revision(&self) -> u64 {
        self.manifest.revision
    }

    /// The store directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// On-disk byte size of shard `(i, j)` — the unit the cache budget
    /// is accounted in.
    pub fn shard_bytes(&self, i: usize, j: usize) -> u64 {
        self.meta(i, j).bytes()
    }

    fn meta(&self, i: usize, j: usize) -> &ShardMeta {
        let gj = self.manifest.grid.1;
        // shards are stored in row-major block order by ingest and
        // validated unique/complete by the manifest parser
        let s = &self.manifest.shards[i * gj + j];
        debug_assert_eq!((s.i, s.j), (i, j));
        s
    }

    /// Read and decode shard `(i, j)` into a centred [`BlockShard`].
    ///
    /// The size is re-checked at read time (the file could have been
    /// truncated after `open`); decode failures are typed errors, never
    /// panics.
    pub fn read_block(&self, i: usize, j: usize) -> Result<BlockShard, StoreError> {
        let s = self.meta(i, j);
        let path = self.dir.join(&s.file);
        let bytes = std::fs::read(&path).map_err(|e| match e.kind() {
            std::io::ErrorKind::NotFound => StoreError::MissingShard { path: path.clone() },
            _ => StoreError::Io { path: path.clone(), source: e },
        })?;
        if bytes.len() as u64 != s.bytes() {
            return Err(StoreError::SizeMismatch {
                path,
                expected: s.bytes(),
                found: bytes.len() as u64,
            });
        }
        let mean = self.manifest.global_mean as f32;
        let mut entries = Vec::with_capacity(s.nnz);
        for rec in bytes.chunks_exact(RECORD_BYTES as usize) {
            let row = u32::from_le_bytes([rec[0], rec[1], rec[2], rec[3]]);
            let col = u32::from_le_bytes([rec[4], rec[5], rec[6], rec[7]]);
            let val = f32::from_le_bytes([rec[8], rec[9], rec[10], rec[11]]);
            if row as usize >= s.rows || col as usize >= s.cols {
                return Err(StoreError::Malformed {
                    path,
                    msg: format!(
                        "entry ({row},{col}) outside the {}x{} block",
                        s.rows, s.cols
                    ),
                });
            }
            // same per-entry centring op as the resident `center()` pass
            entries.push(Entry { row, col, val: val - mean });
        }
        Ok(BlockShard {
            i,
            j,
            coo: Coo { rows: s.rows, cols: s.cols, entries },
        })
    }

    /// The derived partition grid (identical bounds to what the resident
    /// trainer would compute for these dimensions).
    pub fn partition_grid(&self) -> &Grid {
        &self.grid
    }
}

/// Encode a block's entries into the shard wire format (12-byte LE
/// records). Shared with ingest.
pub(crate) fn encode_block(coo: &Coo) -> Vec<u8> {
    let mut out = Vec::with_capacity(coo.entries.len() * RECORD_BYTES as usize);
    for e in &coo.entries {
        out.extend_from_slice(&e.row.to_le_bytes());
        out.extend_from_slice(&e.col.to_le_bytes());
        out.extend_from_slice(&e.val.to_le_bytes());
    }
    out
}

fn verify_shard_file(dir: &Path, s: &ShardMeta) -> Result<(), StoreError> {
    let path = dir.join(&s.file);
    let bytes = std::fs::read(&path).map_err(|e| match e.kind() {
        std::io::ErrorKind::NotFound => StoreError::MissingShard { path: path.clone() },
        _ => StoreError::Io { path: path.clone(), source: e },
    })?;
    if bytes.len() as u64 != s.bytes() {
        return Err(StoreError::SizeMismatch {
            path,
            expected: s.bytes(),
            found: bytes.len() as u64,
        });
    }
    let found = fnv1a64(&bytes);
    if found != s.checksum {
        return Err(StoreError::ChecksumMismatch { path, expected: s.checksum, found });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_is_12_bytes_per_entry_little_endian() {
        let mut coo = Coo::new(4, 4);
        coo.push(1, 2, -1.5);
        let bytes = encode_block(&coo);
        assert_eq!(bytes.len(), 12);
        assert_eq!(&bytes[0..4], &1u32.to_le_bytes());
        assert_eq!(&bytes[4..8], &2u32.to_le_bytes());
        assert_eq!(&bytes[8..12], &(-1.5f32).to_le_bytes());
    }
}
