//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [--key=value] [pos...]`.
//! Unknown flags are an error; values are fetched typed with defaults.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First bare word, if any (the subcommand name).
    pub subcommand: Option<String>,
    /// Remaining bare words after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags actually consumed by `get`/`has` — used for unknown-flag checks.
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` separator: rest is positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless next item is another flag → boolean
                    match it.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() && out.flags.is_empty()
            {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// True if the flag was provided (marks it consumed).
    pub fn has(&self, key: &str) -> bool {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.contains_key(key)
    }

    /// The flag's raw value, if provided (marks it consumed).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    /// The flag's raw value, or `default` when absent.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// The flag parsed as `usize`, or `default` when absent/unparsable.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The flag parsed as `f64`, or `default` when absent/unparsable.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The flag parsed as `u64`, or `default` when absent/unparsable.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// The flag parsed as `bool`, or `default` when absent/unparsable.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Error if any provided flag was never consumed by `get`/`has`. The
    /// message lists the flags this command did consult, so the caller
    /// sees what was accepted next to what was rejected.
    pub fn check_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<_> =
            self.flags.keys().filter(|k| !seen.contains(*k)).cloned().collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            let known: Vec<_> = seen.iter().map(|k| format!("--{k}")).collect();
            let hint = if known.is_empty() {
                "this command takes no flags".to_string()
            } else {
                format!("known flags: {}", known.join(", "))
            };
            Err(format!("unknown flags: {} ({hint})", unknown.join(", ")))
        }
    }

    /// Parse a grid spec like "16x8" into (16, 8).
    pub fn grid_or(&self, key: &str, default: (usize, usize)) -> (usize, usize) {
        match self.get(key) {
            Some(v) => parse_grid(v).unwrap_or(default),
            None => default,
        }
    }
}

/// Parse "IxJ" → (I, J).
pub fn parse_grid(s: &str) -> Option<(usize, usize)> {
    let (a, b) = s.split_once(['x', 'X'])?;
    Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse(items.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--dataset", "netflix", "--grid=16x8", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("dataset"), Some("netflix"));
        assert_eq!(a.grid_or("grid", (1, 1)), (16, 8));
        assert!(a.has("verbose"));
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["x", "--k", "32", "--tau", "1.5"]);
        assert_eq!(a.usize_or("k", 8), 32);
        assert_eq!(a.f64_or("tau", 0.0), 1.5);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["x", "--oops", "1"]);
        assert!(a.check_unknown().is_err());
        let _ = a.get("oops");
        assert!(a.check_unknown().is_ok());
    }

    #[test]
    fn unknown_flag_error_lists_known_flags() {
        let a = parse(&["x", "--k", "3", "--oops", "1"]);
        let _ = a.usize_or("k", 0);
        let _ = a.f64_or("tau", 1.0); // consulted but absent — still "known"
        let err = a.check_unknown().unwrap_err();
        assert!(err.contains("--oops") || err.contains("oops"), "{err}");
        assert!(err.contains("--k") && err.contains("--tau"), "{err}");
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = parse(&["x", "--fast", "--k", "3"]);
        assert!(a.has("fast"));
        assert_eq!(a.usize_or("k", 0), 3);
    }

    #[test]
    fn positional_after_separator() {
        let a = parse(&["run", "--k", "1", "--", "--not-a-flag", "pos2"]);
        assert_eq!(a.positional, vec!["--not-a-flag", "pos2"]);
    }

    #[test]
    fn grid_parsing() {
        assert_eq!(parse_grid("32x32"), Some((32, 32)));
        assert_eq!(parse_grid("1X4"), Some((1, 4)));
        assert_eq!(parse_grid("bad"), None);
    }
}
