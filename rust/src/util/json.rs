//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! stored as f64 (adequate for manifest/config/metrics use). The writer
//! produces deterministic output (object keys keep insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Object. BTreeMap gives deterministic iteration; key order is not
    /// semantically meaningful in JSON.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number truncated to `usize`, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Member lookup, if this is an `Obj`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Builder helper for object literals.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {at}: {msg}")]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub at: usize,
    /// What the parser expected or found.
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { at: self.i, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{s}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match s.parse::<f64>() {
            Ok(n) => Ok(Json::Num(n)),
            Err(_) => self.err("bad number"),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError { at: self.i, msg: "bad hex".into() })?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| JsonError { at: self.i, msg: "bad utf8".into() })?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, out: &mut String, indent: usize, pretty: bool) {
    let pad = |n: usize, o: &mut String| {
        if pretty {
            o.push('\n');
            for _ in 0..n {
                o.push_str("  ");
            }
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(indent + 1, out);
                write_value(item, out, indent + 1, pretty);
            }
            if !a.is_empty() {
                pad(indent, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(indent + 1, out);
                write_escaped(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, out, indent + 1, pretty);
            }
            if !m.is_empty() {
                pad(indent, out);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s, 0, false);
    s
}

/// Serialize with two-space indentation.
pub fn to_string_pretty(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s, 0, true);
    s
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123 xyz").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"artifacts":[{"k":16,"n":256,"name":"x"}],"version":1}"#,
            r#"[1,2.5,"s",true,null,[]]"#,
            r#"{}"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            assert_eq!(parse(&to_string(&v)).unwrap(), v);
            assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
        }
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(to_string(&Json::Num(3.0)), "3");
        assert_eq!(to_string(&Json::Num(3.25)), "3.25");
    }
}
