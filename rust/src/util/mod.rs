//! Shared utility substrates built from scratch (the offline environment has
//! no clap/serde/tracing): a JSON parser/writer, a CLI argument parser, a
//! tiny logger and wall-clock timers.

pub mod cli;
pub mod json;
pub mod logging;
pub mod timer;
