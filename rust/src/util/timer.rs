//! Wall-clock timing helpers and the paper's hh:mm formatting.

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }
    /// Time elapsed since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    /// Seconds elapsed since start.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Format seconds as the paper's tables do: `h:mm` (Table 3) .
pub fn fmt_hhmm(secs: f64) -> String {
    let total_min = (secs / 60.0).round() as u64;
    format!("{}:{:02}", total_min / 60, total_min % 60)
}

/// Format seconds adaptively for logs: ms below 1s, else s / m / h.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2}s")
    } else if secs < 7200.0 {
        format!("{:.1}m", secs / 60.0)
    } else {
        format!("{:.2}h", secs / 3600.0)
    }
}

/// Measure the wall-clock time of `f`, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hhmm_matches_paper_format() {
        assert_eq!(fmt_hhmm(7.0 * 60.0), "0:07");
        assert_eq!(fmt_hhmm(2.0 * 3600.0 + 2.0 * 60.0), "2:02");
        assert_eq!(fmt_hhmm(13.0 * 3600.0 + 2.0 * 60.0), "13:02");
        assert_eq!(fmt_hhmm(0.0), "0:00");
    }

    #[test]
    fn adaptive_format() {
        assert!(fmt_duration(0.002).ends_with("ms"));
        assert!(fmt_duration(3.0).ends_with('s'));
        assert!(fmt_duration(600.0).ends_with('m'));
        assert!(fmt_duration(10_000.0).ends_with('h'));
    }

    #[test]
    fn time_it_returns_result() {
        let (v, t) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
