//! Scenario execution: drive the real [`Engine`] through a parsed
//! [`Scenario`] and collect per-leg observations for the comparator.
//!
//! The executor is deliberately boring: one synthetic dataset and one
//! holdout split per scenario (split seed 7, matching the `train` and
//! `ingest` CLIs), one warm [`Engine`] shared by every leg, and one τ
//! resolved up front so cross-leg bitwise comparisons are exact. Store
//! legs ingest the train split into a scenario-scoped temp directory
//! (once per distinct grid); fault legs run the crash under the leg's
//! [`FaultPlan`] and, when asked, resume from the newest checkpoint
//! generation. Nothing here panics on a failed run — every leg ends as
//! a [`LegResult`] and the invariants decide what that means.

use crate::coordinator::trainer::RunStats;
use crate::coordinator::{BackendSpec, Engine, Session, TrainConfig, TrainOutcome, TrainResult};
use crate::data::split::holdout_split_covered;
use crate::data::{Coo, SyntheticDataset};
use crate::posterior::PosteriorModel;
use crate::store::{ingest, ShardStore};
use crate::testing::fault::FaultPlan;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::spec::{LegSpec, RunSpec, Scenario, Tenancy};

/// Holdout split seed, fixed to match `bmf-pp train`/`ingest` so a
/// scenario's RMSE bound is comparable with the CLI's reported numbers.
const SPLIT_SEED: u64 = 7;

/// How a leg ended, as the comparator sees it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LegOutcome {
    /// Trained to completion (model + stats available).
    Completed,
    /// The run failed — an injected fault, a rejected config, or any
    /// engine error. The detail string says which.
    Failed,
    /// The run was cancelled (not currently produced by any spec knob,
    /// but the engine can report it).
    Cancelled,
}

impl std::fmt::Display for LegOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LegOutcome::Completed => "completed",
            LegOutcome::Failed => "failed",
            LegOutcome::Cancelled => "cancelled",
        })
    }
}

/// Everything observed about one executed leg.
#[derive(Debug)]
pub struct LegResult {
    /// The leg's spec name.
    pub name: String,
    /// Terminal state.
    pub outcome: LegOutcome,
    /// Failure detail when `outcome != Completed`.
    pub error: Option<String>,
    /// The trained posterior (completed legs only).
    pub model: Option<PosteriorModel>,
    /// Run counters (completed legs only).
    pub stats: Option<RunStats>,
    /// Holdout RMSE of `model` (completed legs only).
    pub rmse: Option<f64>,
    /// Blocks restored from checkpoint instead of recomputed — nonzero
    /// proves a resumed leg actually resumed.
    pub blocks_restored: usize,
    /// Wall-clock seconds the leg took (including any crash + resume).
    pub secs: f64,
    /// 0-based completion order across the scenario's legs (in a
    /// sequential scenario this is just the leg index).
    pub finished_rank: usize,
}

impl LegResult {
    fn failed(name: &str, error: String, secs: f64, rank: usize) -> LegResult {
        LegResult {
            name: name.to_string(),
            outcome: LegOutcome::Failed,
            error: Some(error),
            model: None,
            stats: None,
            rmse: None,
            blocks_restored: 0,
            secs,
            finished_rank: rank,
        }
    }
}

/// A scenario-scoped temporary directory, removed on drop. Hand-rolled
/// (no tempfile dep): uniqueness comes from pid + a process-wide counter.
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> std::io::Result<TempDir> {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let path = std::env::temp_dir().join(format!(
            "bmfpp_scenario_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir(path))
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// The execution context a scenario's legs share.
struct Context {
    engine: Engine,
    train: Coo,
    test: Coo,
    k: usize,
    tau: f64,
    /// Ingested shard stores, one per distinct grid (store legs only).
    stores: Mutex<BTreeMap<(usize, usize), Result<Arc<ShardStore>, String>>>,
    /// Keeps store/checkpoint temp directories alive until the scenario ends.
    scratch: Mutex<Vec<TempDir>>,
}

impl Context {
    fn config(&self, run: &RunSpec) -> TrainConfig {
        TrainConfig::new(self.k)
            .with_backend(BackendSpec::Native)
            .with_grid(run.grid.0, run.grid.1)
            .with_sweeps(run.burnin, run.samples)
            .with_seed(run.seed)
            .with_workers(run.workers.max(1))
            .with_tau(run.tau.unwrap_or(self.tau))
            .with_sweep_mode(run.sweep)
            .with_chunk_rows(run.chunk_rows)
            .with_staleness(run.staleness)
            .with_scheduler(run.scheduler)
            .with_priority(run.priority)
            .with_max_in_flight(run.max_in_flight)
    }

    /// The shard store for `grid`, ingesting the train split on first use.
    fn store_for(&self, grid: (usize, usize)) -> Result<Arc<ShardStore>, String> {
        let mut stores = self.stores.lock().unwrap();
        if let Some(cached) = stores.get(&grid) {
            return cached.clone();
        }
        let built = self.ingest_store(grid);
        stores.insert(grid, built.clone());
        built
    }

    fn ingest_store(&self, grid: (usize, usize)) -> Result<Arc<ShardStore>, String> {
        let dir = TempDir::new(&format!("store_{}x{}", grid.0, grid.1))
            .map_err(|e| format!("cannot create store dir: {e}"))?;
        ingest(&self.train, grid.0, grid.1, &dir.0).map_err(|e| e.to_string())?;
        let store = ShardStore::open(&dir.0).map_err(|e| e.to_string())?;
        self.scratch.lock().unwrap().push(dir);
        Ok(Arc::new(store))
    }

    fn submit(&self, cfg: TrainConfig, leg: &LegSpec) -> anyhow::Result<Session> {
        if leg.store {
            let store = self.store_for(leg.run.grid).map_err(anyhow::Error::msg)?;
            let cfg =
                if leg.cache_bytes > 0 { cfg.with_cache_bytes(leg.cache_bytes) } else { cfg };
            self.engine.submit_store(cfg, store)
        } else {
            self.engine.submit(cfg, &self.train)
        }
    }
}

/// One fully-executed scenario, ready for the comparator/reporter.
#[derive(Debug)]
pub struct ScenarioRun {
    /// The scenario's name.
    pub name: String,
    /// The file it came from (re-run hints).
    pub path: String,
    /// Per-leg observations, in spec order.
    pub legs: Vec<LegResult>,
    /// Wall-clock seconds for the whole scenario.
    pub secs: f64,
}

impl ScenarioRun {
    /// The result for leg `name` (validated to exist at parse time).
    pub fn leg(&self, name: &str) -> Option<&LegResult> {
        self.legs.iter().find(|l| l.name == name)
    }
}

/// Execute every leg of `scn` against a fresh engine. Run-time failures
/// (engine errors, injected faults, store errors) are captured in the
/// returned [`LegResult`]s — this function only errors when the scenario
/// cannot be set up at all (unknown dataset profile escaping validation
/// is impossible, so in practice: never for a parsed spec).
pub fn run_scenario(scn: &Scenario) -> anyhow::Result<ScenarioRun> {
    let started = Instant::now();
    let ds = SyntheticDataset::by_name(&scn.dataset.profile, scn.dataset.scale, scn.dataset.seed)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset profile '{}'", scn.dataset.profile))?;
    let (train, test) = holdout_split_covered(&ds.ratings, scn.dataset.test_frac, SPLIT_SEED);
    let tau = scn.base.tau.unwrap_or_else(|| crate::coordinator::config::auto_tau(&train));
    let ctx = Context {
        engine: Engine::new(&BackendSpec::Native, scn.threads),
        train,
        test,
        k: scn.dataset.k.unwrap_or(ds.k),
        tau,
        stores: Mutex::new(BTreeMap::new()),
        scratch: Mutex::new(Vec::new()),
    };

    let legs = match scn.tenancy {
        Tenancy::Sequential => run_sequential(&ctx, scn),
        Tenancy::Concurrent => run_concurrent(&ctx, scn),
    };

    Ok(ScenarioRun {
        name: scn.name.clone(),
        path: scn.display_path(),
        legs,
        secs: started.elapsed().as_secs_f64(),
    })
}

fn run_sequential(ctx: &Context, scn: &Scenario) -> Vec<LegResult> {
    // legs some later update leg seeds from must checkpoint, into a
    // directory that outlives them
    let referenced: std::collections::BTreeSet<&str> =
        scn.legs.iter().filter_map(|l| l.update_from.as_deref()).collect();
    let mut ckpt_dirs: BTreeMap<String, PathBuf> = BTreeMap::new();
    let mut results = Vec::with_capacity(scn.legs.len());
    for (rank, leg) in scn.legs.iter().enumerate() {
        let result = if leg.update_from.is_some() {
            run_update_leg(ctx, leg, rank, &ckpt_dirs)
        } else if leg.fault_block.is_some() {
            run_fault_leg(ctx, leg, rank)
        } else if referenced.contains(leg.name.as_str()) {
            run_checkpointed_leg(ctx, leg, rank, &mut ckpt_dirs)
        } else {
            run_plain_leg(ctx, leg, rank)
        };
        results.push(result);
    }
    results
}

/// Submit every leg up front (in spec order) and let the engine's shared
/// priority queue interleave them; completion order is observed for the
/// `finish_before` invariant.
fn run_concurrent(ctx: &Context, scn: &Scenario) -> Vec<LegResult> {
    let started = Instant::now();
    let mut submitted = Vec::with_capacity(scn.legs.len());
    for leg in &scn.legs {
        let cfg = ctx.config(&leg.run);
        submitted.push((leg, ctx.submit(cfg, leg)));
    }
    let finish_order: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let mut results: Vec<LegResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = submitted
            .into_iter()
            .map(|(leg, session)| {
                let order = &finish_order;
                scope.spawn(move || match session {
                    Err(e) => {
                        order.lock().unwrap().push(leg.name.clone());
                        let secs = started.elapsed().as_secs_f64();
                        LegResult::failed(&leg.name, e.to_string(), secs, 0)
                    }
                    Ok(session) => {
                        let outcome = session.wait();
                        order.lock().unwrap().push(leg.name.clone());
                        finish_leg(ctx, &leg.name, outcome, started.elapsed().as_secs_f64(), 0)
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("leg thread panicked")).collect()
    });
    let order = finish_order.into_inner().unwrap();
    for leg in &mut results {
        leg.finished_rank = order.iter().position(|n| n == &leg.name).unwrap_or(usize::MAX);
    }
    results
}

fn run_plain_leg(ctx: &Context, leg: &LegSpec, rank: usize) -> LegResult {
    let started = Instant::now();
    let mut cfg = ctx.config(&leg.run);
    if leg.checkpoint_every > 0 {
        match TempDir::new("ckpt") {
            Ok(dir) => {
                cfg = cfg.with_checkpoint_every(leg.checkpoint_every).with_checkpoint_dir(&dir.0);
                ctx.scratch.lock().unwrap().push(dir);
            }
            Err(e) => {
                return LegResult::failed(
                    &leg.name,
                    format!("cannot create checkpoint dir: {e}"),
                    started.elapsed().as_secs_f64(),
                    rank,
                )
            }
        }
    }
    let outcome = ctx.submit(cfg, leg).and_then(|s| s.wait());
    finish_leg(ctx, &leg.name, outcome, started.elapsed().as_secs_f64(), rank)
}

/// A leg some later update leg seeds from: force checkpointing after
/// *every* block (so the final generation is complete — a sparser
/// interval could leave the newest generation mid-run) into a retained
/// directory, recorded under the leg's name for the update to find.
fn run_checkpointed_leg(
    ctx: &Context,
    leg: &LegSpec,
    rank: usize,
    ckpt_dirs: &mut BTreeMap<String, PathBuf>,
) -> LegResult {
    let started = Instant::now();
    let dir = match TempDir::new("update_base") {
        Ok(dir) => dir,
        Err(e) => {
            return LegResult::failed(
                &leg.name,
                format!("cannot create checkpoint dir: {e}"),
                started.elapsed().as_secs_f64(),
                rank,
            )
        }
    };
    let cfg = ctx.config(&leg.run).with_checkpoint_every(1).with_checkpoint_dir(&dir.0);
    ckpt_dirs.insert(leg.name.clone(), dir.0.clone());
    ctx.scratch.lock().unwrap().push(dir);
    let outcome = ctx.submit(cfg, leg).and_then(|s| s.wait());
    finish_leg(ctx, &leg.name, outcome, started.elapsed().as_secs_f64(), rank)
}

/// An update leg: load the referenced leg's final checkpoint as the
/// prior, synthesize the drift delta, and run `Engine::update` — the
/// pruned-resume path that re-samples only dirty blocks.
fn run_update_leg(
    ctx: &Context,
    leg: &LegSpec,
    rank: usize,
    ckpt_dirs: &BTreeMap<String, PathBuf>,
) -> LegResult {
    let started = Instant::now();
    let from = leg.update_from.as_deref().expect("update leg without update_from");
    let Some(dir) = ckpt_dirs.get(from) else {
        return LegResult::failed(
            &leg.name,
            format!("update_from leg '{from}' left no checkpoint directory (did it fail?)"),
            started.elapsed().as_secs_f64(),
            rank,
        );
    };
    let prior = match crate::online::load_prior(dir) {
        Ok(p) => p,
        Err(e) => {
            return LegResult::failed(
                &leg.name,
                format!("cannot load update prior: {e}"),
                started.elapsed().as_secs_f64(),
                rank,
            )
        }
    };
    let delta = synthesize_delta(&ctx.train, leg.run.grid, leg.delta_frac);
    let cfg = ctx.config(&leg.run);
    let outcome = ctx.engine.update(cfg, &prior, &delta, &ctx.train).and_then(|s| s.wait());
    finish_leg(ctx, &leg.name, outcome, started.elapsed().as_secs_f64(), rank)
}

/// Deterministic drift confined to block (0,0): every `stride`-th train
/// entry inside the block is re-rated at `+0.25`, so the delta's size
/// tracks `frac` while dirtying exactly one block — the scenario can
/// then pin `max_blocks_resampled` to 1. `frac == 0.0` returns the
/// empty delta (the bitwise no-op case).
fn synthesize_delta(train: &Coo, grid: (usize, usize), frac: f64) -> crate::online::RatingDelta {
    let mut delta = crate::online::RatingDelta::new(train.rows, train.cols);
    if frac <= 0.0 {
        return delta;
    }
    let g = crate::partition::Grid::new(train.rows, train.cols, grid.0, grid.1);
    let (_, row_end) = g.row_range(0);
    let (_, col_end) = g.col_range(0);
    let stride = ((1.0 / frac) as usize).max(1);
    let in_block =
        train.entries.iter().filter(|e| (e.row as usize) < row_end && (e.col as usize) < col_end);
    for (idx, e) in in_block.enumerate() {
        if idx % stride == 0 {
            delta.push(e.row as usize, e.col as usize, e.val + 0.25);
        }
    }
    delta
}

/// Run the leg with its fault plan armed (crash expected), then — when
/// the leg opts into resume — rerun the identical config without the
/// fault, restoring from the checkpoint generations the crashed run
/// left behind. The *resumed* run is the leg's reported result.
fn run_fault_leg(ctx: &Context, leg: &LegSpec, rank: usize) -> LegResult {
    let started = Instant::now();
    let block = leg.fault_block.expect("fault leg without fault_block");
    let ckpt = match TempDir::new("fault_ckpt") {
        Ok(dir) => dir,
        Err(e) => {
            return LegResult::failed(
                &leg.name,
                format!("cannot create checkpoint dir: {e}"),
                started.elapsed().as_secs_f64(),
                rank,
            )
        }
    };
    let mut crash_cfg = ctx.config(&leg.run).with_fault_plan(FaultPlan::panic_at_block(block));
    if leg.checkpoint_every > 0 {
        crash_cfg =
            crash_cfg.with_checkpoint_every(leg.checkpoint_every).with_checkpoint_dir(&ckpt.0);
    }
    let crash = ctx.submit(crash_cfg, leg).and_then(|s| s.wait());
    match crash {
        Err(e) => {
            let secs = started.elapsed().as_secs_f64();
            return LegResult::failed(&leg.name, e.to_string(), secs, rank);
        }
        Ok(TrainOutcome::Failed(_)) if !leg.resume => {
            // The failure IS the expected observation (expect_outcome: failed).
            return LegResult::failed(
                &leg.name,
                format!("injected fault at block {block}"),
                started.elapsed().as_secs_f64(),
                rank,
            );
        }
        Ok(TrainOutcome::Failed(_)) => {} // expected crash; fall through to resume
        Ok(other) => {
            return LegResult::failed(
                &leg.name,
                format!(
                    "fault at block {block} did not fire: run ended {}",
                    outcome_name(&other)
                ),
                started.elapsed().as_secs_f64(),
                rank,
            )
        }
    }
    let resume_cfg = ctx.config(&leg.run).with_resume_from(&ckpt.0);
    let outcome = ctx.submit(resume_cfg, leg).and_then(|s| s.wait());
    ctx.scratch.lock().unwrap().push(ckpt);
    finish_leg(ctx, &leg.name, outcome, started.elapsed().as_secs_f64(), rank)
}

fn outcome_name(outcome: &TrainOutcome) -> &'static str {
    match outcome {
        TrainOutcome::Completed(_) => "completed",
        TrainOutcome::Cancelled(_) => "cancelled",
        TrainOutcome::Failed(_) => "failed",
    }
}

fn finish_leg(
    ctx: &Context,
    name: &str,
    outcome: anyhow::Result<TrainOutcome>,
    secs: f64,
    rank: usize,
) -> LegResult {
    match outcome {
        Err(e) => LegResult::failed(name, e.to_string(), secs, rank),
        Ok(TrainOutcome::Completed(result)) => completed_leg(ctx, name, *result, secs, rank),
        Ok(TrainOutcome::Cancelled(info)) => LegResult {
            name: name.to_string(),
            outcome: LegOutcome::Cancelled,
            error: Some(format!("cancelled after {} blocks", info.blocks_completed)),
            model: None,
            stats: None,
            rmse: None,
            blocks_restored: 0,
            secs,
            finished_rank: rank,
        },
        Ok(TrainOutcome::Failed(info)) => LegResult::failed(name, info.error, secs, rank),
    }
}

fn completed_leg(
    ctx: &Context,
    name: &str,
    result: TrainResult,
    secs: f64,
    rank: usize,
) -> LegResult {
    let rmse = result.model.rmse(&ctx.test);
    let stats = result.stats;
    LegResult {
        name: name.to_string(),
        outcome: LegOutcome::Completed,
        error: None,
        blocks_restored: stats.blocks_restored,
        rmse: Some(rmse),
        stats: Some(stats),
        model: Some(result.into_model()),
        secs,
        finished_rank: rank,
    }
}
