//! Scenario reporting: a human table on stdout and a machine-readable
//! JSON report (the artifact CI uploads).
//!
//! The human output prints one block per scenario — its legs with
//! outcome/RMSE/timing, then each invariant as `PASS`/`FAIL` with the
//! comparator's observed detail — and every failing scenario ends with
//! the exact `bmf-pp scenario <file>` line that reproduces it alone.
//! The JSON report mirrors the same data (`version: 1`) via
//! [`crate::util::json`], so downstream tooling needs no extra parser.

use crate::util::json::Json;
use std::fmt::Write as _;

use super::comparator::CheckResult;
use super::executor::{LegOutcome, ScenarioRun};

/// One scenario's executed legs plus its evaluated invariants.
#[derive(Debug)]
pub struct ScenarioReport {
    /// The executed scenario.
    pub run: ScenarioRun,
    /// The comparator's verdicts, in spec order.
    pub checks: Vec<CheckResult>,
}

impl ScenarioReport {
    /// A scenario passes iff every invariant held.
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }
}

/// Render the human block for one scenario (what `bmf-pp scenario`
/// prints as each spec finishes).
pub fn render_human(report: &ScenarioReport) -> String {
    let mut out = String::new();
    let verdict = if report.passed() { "PASS" } else { "FAIL" };
    let _ = writeln!(
        out,
        "[{verdict}] {}  ({} legs, {:.1}s)",
        report.run.name,
        report.run.legs.len(),
        report.run.secs
    );
    for leg in &report.run.legs {
        let rmse = leg.rmse.map(|r| format!("rmse {r:.4}")).unwrap_or_else(|| "-".into());
        let extra = match (&leg.outcome, &leg.error) {
            (LegOutcome::Completed, _) if leg.blocks_restored > 0 => {
                format!("  ({} blocks restored)", leg.blocks_restored)
            }
            (LegOutcome::Completed, _) => String::new(),
            (_, Some(e)) => format!("  ({e})"),
            (_, None) => String::new(),
        };
        let _ = writeln!(
            out,
            "  leg {:<14} {:<9} {:<12} {:>6.1}s{extra}",
            leg.name, leg.outcome, rmse, leg.secs
        );
    }
    for check in &report.checks {
        let mark = if check.passed { "PASS" } else { "FAIL" };
        let _ = writeln!(out, "  [{mark}] {:<40} {}", check.invariant, check.detail);
    }
    if !report.passed() {
        let _ = writeln!(out, "  re-run: bmf-pp scenario {}", report.run.path);
    }
    out
}

/// Render the one-line sweep summary printed after all scenarios ran.
pub fn render_summary(reports: &[ScenarioReport]) -> String {
    let passed = reports.iter().filter(|r| r.passed()).count();
    let mut out = format!("scenarios: {passed}/{} passed", reports.len());
    for report in reports.iter().filter(|r| !r.passed()) {
        let _ = write!(
            out,
            "\n  FAIL {}  — re-run: bmf-pp scenario {}",
            report.run.name, report.run.path
        );
    }
    out
}

/// Build the machine JSON report (`{"version": 1, ...}`) for `--report`.
pub fn to_json(reports: &[ScenarioReport]) -> Json {
    let passed = reports.iter().filter(|r| r.passed()).count();
    Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("total", Json::Num(reports.len() as f64)),
        ("passed", Json::Num(passed as f64)),
        ("failed", Json::Num((reports.len() - passed) as f64)),
        ("scenarios", Json::Arr(reports.iter().map(scenario_json).collect())),
    ])
}

fn scenario_json(report: &ScenarioReport) -> Json {
    Json::obj(vec![
        ("name", Json::Str(report.run.name.clone())),
        ("file", Json::Str(report.run.path.clone())),
        ("passed", Json::Bool(report.passed())),
        ("secs", Json::Num(report.run.secs)),
        (
            "legs",
            Json::Arr(
                report
                    .run
                    .legs
                    .iter()
                    .map(|leg| {
                        let mut fields = vec![
                            ("name", Json::Str(leg.name.clone())),
                            ("outcome", Json::Str(leg.outcome.to_string())),
                            ("secs", Json::Num(leg.secs)),
                            ("finished_rank", Json::Num(leg.finished_rank as f64)),
                            ("blocks_restored", Json::Num(leg.blocks_restored as f64)),
                        ];
                        if let Some(rmse) = leg.rmse {
                            fields.push(("rmse", Json::Num(rmse)));
                        }
                        if let Some(stats) = &leg.stats {
                            let evictions = stats.shard_evictions as f64;
                            fields.push(("queue_wait_secs", Json::Num(stats.queue_wait_secs)));
                            fields.push(("shard_evictions", Json::Num(evictions)));
                        }
                        if let Some(err) = &leg.error {
                            fields.push(("error", Json::Str(err.clone())));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
        (
            "invariants",
            Json::Arr(
                report
                    .checks
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("invariant", Json::Str(c.invariant.clone())),
                            ("passed", Json::Bool(c.passed)),
                            ("detail", Json::Str(c.detail.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
