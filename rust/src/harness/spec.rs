//! Declarative scenario specs: JSON → typed [`Scenario`].
//!
//! A scenario file describes an end-to-end exercise of the training
//! stack as data: the synthetic dataset to generate, a base training
//! configuration, a list of **legs** (runs that vary one or more knobs —
//! sweep mode, scheduler, store-backed vs resident, a fault plan), and a
//! list of **invariants** the executed legs must satisfy. Parsing is
//! strict: unknown keys, wrong types, and inconsistent combinations
//! (staleness on a lockstep leg, a fault plan with no checkpointing
//! armed) are typed [`SpecError`]s at load time — a malformed spec never
//! reaches the engine, let alone panics.
//!
//! The JSON grammar (parsed with [`crate::util::json`]; no external
//! deps):
//!
//! ```json
//! {
//!   "name": "tau0-pipelined-bitwise",
//!   "description": "pipelined tau=0 must equal lockstep bitwise",
//!   "dataset": {"profile": "movielens", "scale": 0.002, "seed": 11},
//!   "config": {"grid": "3x3", "burnin": 6, "samples": 12, "seed": 11},
//!   "legs": [
//!     {"name": "lockstep"},
//!     {"name": "pipelined", "sweep": "pipelined", "staleness": 0}
//!   ],
//!   "invariants": [
//!     {"check": "bitwise_equal", "legs": ["lockstep", "pipelined"]},
//!     {"check": "rmse_max", "leg": "lockstep", "max": 1.6}
//!   ]
//! }
//! ```
//!
//! Every `config` key may be overridden per leg; leg-only keys add the
//! store-backed, fault-injection, and checkpointing dimensions.

use crate::coordinator::{Priority, SchedulerMode, SweepMode};
use crate::data::generator::DatasetProfile;
use crate::util::cli::parse_grid;
use crate::util::json::{self, Json, JsonError};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Why a scenario file was rejected. Every variant names the offending
/// section/field so the fix is obvious from the message alone; the CLI
/// prints these and exits non-zero without running anything.
#[derive(Debug, thiserror::Error)]
pub enum SpecError {
    /// The file (or directory) could not be read.
    #[error("cannot read scenario {path}: {source}")]
    Io {
        /// The path that failed.
        path: PathBuf,
        /// The underlying filesystem error.
        source: std::io::Error,
    },
    /// The file is not valid JSON.
    #[error("scenario {path} is not valid JSON: {source}")]
    Json {
        /// The file that failed to parse.
        path: PathBuf,
        /// The parser's error (with byte offset).
        source: JsonError,
    },
    /// A section that must be a JSON object (or array) is something else.
    #[error("scenario section '{section}' must be {expected}")]
    WrongShape {
        /// The section (dotted path) with the wrong shape.
        section: String,
        /// What the parser expected there.
        expected: &'static str,
    },
    /// An object contains a key the schema does not define — almost
    /// always a typo; listing the accepted keys makes it self-healing.
    #[error("unknown key '{key}' in '{section}' (accepted: {})", known.join(", "))]
    UnknownKey {
        /// The section (dotted path) holding the unknown key.
        section: String,
        /// The rejected key.
        key: String,
        /// Keys the section accepts.
        known: Vec<&'static str>,
    },
    /// A required field is absent.
    #[error("'{section}' is missing required field '{field}'")]
    MissingField {
        /// The section (dotted path) missing the field.
        section: String,
        /// The absent field.
        field: &'static str,
    },
    /// A field is present but its value is unusable (wrong type, unknown
    /// enum name, out of range).
    #[error("bad value for '{section}.{field}': got {got}, expected {expected}")]
    BadValue {
        /// The section (dotted path) holding the field.
        section: String,
        /// The offending field.
        field: String,
        /// The value found, rendered.
        got: String,
        /// What would have been accepted.
        expected: String,
    },
    /// Two legs share a name — invariants reference legs by name, so
    /// names must be unique.
    #[error("duplicate leg name '{name}'")]
    DuplicateLeg {
        /// The repeated name.
        name: String,
    },
    /// The scenario has no legs to run.
    #[error("scenario '{scenario}' declares no legs")]
    NoLegs {
        /// The offending scenario.
        scenario: String,
    },
    /// The scenario has no invariants — it would always "pass", which is
    /// a spec bug, not a test.
    #[error("scenario '{scenario}' declares no invariants")]
    NoInvariants {
        /// The offending scenario.
        scenario: String,
    },
    /// An invariant references a leg name no leg declares.
    #[error("invariant '{invariant}' references unknown leg '{leg}'")]
    UnknownLeg {
        /// The invariant (rendered) holding the reference.
        invariant: String,
        /// The dangling leg name.
        leg: String,
    },
    /// `staleness > 0` on a leg whose effective sweep mode is lockstep:
    /// the staleness bound τ only exists in the pipelined exchange.
    #[error(
        "leg '{leg}' sets staleness {staleness} under lockstep sweeps — \
         the staleness bound only applies to sweep \"pipelined\""
    )]
    StalenessOnLockstep {
        /// The offending leg.
        leg: String,
        /// The staleness it asked for.
        staleness: usize,
    },
    /// A fault-injected leg that wants to resume has no periodic
    /// checkpointing armed — there would be nothing to resume from.
    #[error(
        "leg '{leg}' injects a fault but arms no checkpointing \
         (set checkpoint_every >= 1, or resume: false to assert the failure)"
    )]
    FaultWithoutCheckpoint {
        /// The offending leg.
        leg: String,
    },
    /// Fault-injected legs need the deterministic sequential executor;
    /// concurrent tenancy would race the crash against its neighbours.
    #[error("leg '{leg}' injects a fault in a concurrent scenario — use sequential tenancy")]
    FaultInConcurrent {
        /// The offending leg.
        leg: String,
    },
    /// An `update_from` reference that does not name an *earlier* leg:
    /// the referenced run's final checkpoint is the update's prior, so it
    /// must already have executed.
    #[error(
        "leg '{leg}' updates from '{from}', which must name an earlier \
         non-update, non-fault leg in the same scenario"
    )]
    UpdateFromNotEarlier {
        /// The update leg.
        leg: String,
        /// The dangling or out-of-order reference.
        from: String,
    },
    /// Update legs need the deterministic sequential executor — the
    /// prior leg's checkpoint must exist before the update starts.
    #[error("leg '{leg}' sets update_from in a concurrent scenario — use sequential tenancy")]
    UpdateInConcurrent {
        /// The offending leg.
        leg: String,
    },
    /// An update leg combined with a knob that contradicts it: the leg
    /// re-runs the referenced leg's configuration over delta'd data, so
    /// only the delta may vary.
    #[error(
        "leg '{leg}' combines update_from with {conflict} — an update leg \
         replays the referenced leg's run over the delta; vary only delta_frac"
    )]
    UpdateConflict {
        /// The offending leg.
        leg: String,
        /// The incompatible knob.
        conflict: &'static str,
    },
    /// `delta_frac` on a leg that is not an update leg — the drift delta
    /// only exists relative to an `update_from` prior.
    #[error("leg '{leg}' sets delta_frac without update_from")]
    DeltaWithoutUpdate {
        /// The offending leg.
        leg: String,
    },
    /// A directory sweep found no scenario files at all.
    #[error("no scenario files (*.json) found under {path}")]
    NoScenarios {
        /// The directory that was swept.
        path: PathBuf,
    },
}

/// Synthetic-dataset parameters for a scenario (section `dataset`).
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    /// Synthetic profile name ("movielens", "netflix", "yahoo", "amazon").
    /// The skewed-nnz profiles (yahoo, amazon) give long-tailed blocks.
    pub profile: String,
    /// Profile scale factor (fraction of the paper-sized matrix).
    pub scale: f64,
    /// Generator seed.
    pub seed: u64,
    /// Latent dimension override (`None` = the profile's K).
    pub k: Option<usize>,
    /// Held-out fraction for the RMSE invariants (split seed is fixed at
    /// 7, matching the CLI's `train`/`ingest`).
    pub test_frac: f64,
}

/// The training knobs a scenario (and each leg, by override) controls —
/// the declarative mirror of `TrainConfig`.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Block grid (I row-blocks × J column-blocks).
    pub grid: (usize, usize),
    /// Burn-in sweeps per block.
    pub burnin: usize,
    /// Retained samples per block.
    pub samples: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Within-block shard workers.
    pub workers: usize,
    /// Noise precision τ; `None` derives `auto_tau` from the train split
    /// (the same value for every leg, so cross-leg comparisons stay exact).
    pub tau: Option<f64>,
    /// Lockstep vs pipelined within-block half-sweeps.
    pub sweep: SweepMode,
    /// Rows per published chunk (pipelined only).
    pub chunk_rows: usize,
    /// Staleness bound in chunks (pipelined only; 0 = bitwise-lockstep).
    pub staleness: usize,
    /// Barrier vs dependency-driven block scheduling.
    pub scheduler: SchedulerMode,
    /// Dispatch priority in the engine's shared queue.
    pub priority: Priority,
    /// Per-job in-flight block cap (0 = pool width).
    pub max_in_flight: usize,
}

impl Default for RunSpec {
    fn default() -> RunSpec {
        RunSpec {
            grid: (2, 2),
            burnin: 4,
            samples: 8,
            seed: 42,
            workers: 1,
            tau: None,
            sweep: SweepMode::Lockstep,
            chunk_rows: 256,
            staleness: 0,
            scheduler: SchedulerMode::Dag,
            priority: Priority::Normal,
            max_in_flight: 0,
        }
    }
}

/// One run of the engine inside a scenario. A leg inherits the
/// scenario's `config` and overrides any subset of it, plus the
/// leg-only dimensions (store-backed data, fault injection,
/// checkpointing).
#[derive(Debug, Clone)]
pub struct LegSpec {
    /// Unique name invariants reference this leg by.
    pub name: String,
    /// The leg's effective training knobs (base config + overrides).
    pub run: RunSpec,
    /// Train out-of-core: ingest the train split into a shard store
    /// (once per distinct grid) and stream blocks through the cache.
    pub store: bool,
    /// Shard-cache byte budget for a store leg (0 = unbounded). A budget
    /// far below the store size forces evictions — pair with the
    /// `min_evictions` invariant.
    pub cache_bytes: u64,
    /// Deterministic crash: panic when the block with this canonical
    /// index starts sampling (see `testing::fault::FaultPlan`).
    pub fault_block: Option<usize>,
    /// After the injected crash, resume from the newest checkpoint
    /// generation and report the *resumed* run as the leg's result
    /// (default). `false` reports the crashed run itself — pair with
    /// `expect_outcome: failed`.
    pub resume: bool,
    /// Periodic checkpoint interval in blocks (0 = off). Required ≥ 1
    /// when `fault_block` is set with `resume: true`; the harness
    /// provides the (temporary) generation directory itself.
    pub checkpoint_every: usize,
    /// Run this leg as an *incremental update* seeded by the named
    /// earlier leg's final checkpoint: the executor forces
    /// checkpointing onto the referenced leg, synthesizes a
    /// deterministic drift delta ([`delta_frac`](LegSpec::delta_frac)),
    /// and calls `Engine::update` instead of a fresh submit. Pair with
    /// `max_blocks_resampled` / `bitwise_equal` invariants.
    pub update_from: Option<String>,
    /// Fraction of the training entries inside block (0,0) the synthetic
    /// drift delta re-rates (each bumped by a fixed +0.25). `0.0` (the
    /// default) is the *empty* delta — the bitwise no-op case. Only
    /// meaningful with [`update_from`](LegSpec::update_from).
    pub delta_frac: f64,
}

/// How a scenario's legs share the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tenancy {
    /// Legs run one after another on the same warm pool (the default) —
    /// the mode for bitwise-pair and fault/resume scenarios.
    Sequential,
    /// All legs are submitted at once and interleave on the shared
    /// priority queue — the multi-tenant mode, for `finish_before` /
    /// `max_queue_wait_secs` invariants.
    Concurrent,
}

/// What a leg is expected to end as (`expect_outcome` invariant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpectedOutcome {
    /// The run trained to completion.
    Completed,
    /// The run failed (a fault-injected leg with `resume: false`).
    Failed,
}

impl std::fmt::Display for ExpectedOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExpectedOutcome::Completed => "completed",
            ExpectedOutcome::Failed => "failed",
        })
    }
}

/// A declarative check over the executed legs (section `invariants`).
/// The comparator evaluates each against the `LegResult`s; any failure
/// fails the scenario (and the CLI exit code).
#[derive(Debug, Clone)]
pub enum Invariant {
    /// The leg's holdout RMSE must be ≤ `max` (and finite).
    RmseMax {
        /// Leg to score.
        leg: String,
        /// Inclusive RMSE bound.
        max: f64,
    },
    /// All named legs must produce bit-for-bit identical posteriors —
    /// the repo's strongest equivalence (store ≡ resident, τ=0 pipelined
    /// ≡ lockstep, DAG ≡ barrier, interleaved ≡ interleaved).
    BitwiseEqual {
        /// Legs whose models must match exactly (≥ 2).
        legs: Vec<String>,
    },
    /// The leg's measured dispatch delay (`RunStats::queue_wait_secs`)
    /// must be ≤ `max` seconds — the multi-tenant fairness bound.
    MaxQueueWaitSecs {
        /// Leg whose queue wait is bounded.
        leg: String,
        /// Inclusive bound in seconds.
        max: f64,
    },
    /// A store-backed leg must have evicted at least `min` shards — the
    /// proof its cache budget actually bounded the working set.
    MinEvictions {
        /// Leg whose evictions are counted.
        leg: String,
        /// Inclusive eviction floor.
        min: u64,
    },
    /// The leg must end in the given state.
    ExpectOutcome {
        /// Leg to check.
        leg: String,
        /// Required terminal state.
        outcome: ExpectedOutcome,
    },
    /// `resumed` (a fault-injected leg that resumed from its crash
    /// checkpoint) must have restored at least one block AND match
    /// `reference` (an uninterrupted leg) bit for bit — crash → resume
    /// is the same computation.
    ResumeBitwise {
        /// The crashed-and-resumed leg.
        resumed: String,
        /// The uninterrupted reference leg.
        reference: String,
    },
    /// In a concurrent scenario, leg `first` must reach its terminal
    /// state before leg `then` — e.g. a small High-priority job landing
    /// ahead of a wide Low-priority one submitted first.
    FinishBefore {
        /// Leg required to finish first.
        first: String,
        /// Leg required to finish after.
        then: String,
    },
    /// The leg must have re-sampled at most `max` blocks
    /// (`RunStats::blocks`; restored and clean-skipped blocks do not
    /// count) — the proof an incremental update touched exactly its
    /// dirty set. `max: 0` asserts a pure pass-through (empty delta).
    MaxBlocksResampled {
        /// Leg whose sampled-block count is bounded.
        leg: String,
        /// Inclusive re-sample ceiling.
        max: usize,
    },
}

impl Invariant {
    /// Compact rendering ("bitwise_equal(a, b)") for tables and errors.
    pub fn label(&self) -> String {
        match self {
            Invariant::RmseMax { leg, max } => format!("rmse_max({leg} <= {max})"),
            Invariant::BitwiseEqual { legs } => format!("bitwise_equal({})", legs.join(", ")),
            Invariant::MaxQueueWaitSecs { leg, max } => {
                format!("max_queue_wait_secs({leg} <= {max})")
            }
            Invariant::MinEvictions { leg, min } => format!("min_evictions({leg} >= {min})"),
            Invariant::ExpectOutcome { leg, outcome } => {
                format!("expect_outcome({leg} = {outcome})")
            }
            Invariant::ResumeBitwise { resumed, reference } => {
                format!("resume_bitwise({resumed} == {reference})")
            }
            Invariant::FinishBefore { first, then } => format!("finish_before({first} < {then})"),
            Invariant::MaxBlocksResampled { leg, max } => {
                format!("max_blocks_resampled({leg} <= {max})")
            }
        }
    }

    /// Leg names this invariant references (for existence validation).
    fn legs(&self) -> Vec<&str> {
        match self {
            Invariant::RmseMax { leg, .. }
            | Invariant::MaxQueueWaitSecs { leg, .. }
            | Invariant::MinEvictions { leg, .. }
            | Invariant::ExpectOutcome { leg, .. }
            | Invariant::MaxBlocksResampled { leg, .. } => vec![leg],
            Invariant::BitwiseEqual { legs } => legs.iter().map(String::as_str).collect(),
            Invariant::ResumeBitwise { resumed, reference } => vec![resumed, reference],
            Invariant::FinishBefore { first, then } => vec![first, then],
        }
    }
}

/// A fully-parsed, validated scenario, ready for the executor.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Unique name (`--filter` matches on it).
    pub name: String,
    /// One-line human description.
    pub description: String,
    /// The file this scenario was loaded from (`None` for in-code specs).
    pub path: Option<PathBuf>,
    /// Synthetic dataset to generate.
    pub dataset: DatasetSpec,
    /// Base training knobs every leg inherits.
    pub base: RunSpec,
    /// Sequential (default) or concurrent leg execution.
    pub tenancy: Tenancy,
    /// Engine worker threads shared by the legs.
    pub threads: usize,
    /// The runs to execute.
    pub legs: Vec<LegSpec>,
    /// The checks that decide pass/fail.
    pub invariants: Vec<Invariant>,
}

impl Scenario {
    /// Parse and validate a scenario from JSON text. `path` is recorded
    /// for re-run hints and error messages (pass the file's path, or a
    /// placeholder like `<inline>` for generated specs).
    pub fn parse(text: &str, path: impl Into<PathBuf>) -> Result<Scenario, SpecError> {
        let path = path.into();
        let root = json::parse(text)
            .map_err(|source| SpecError::Json { path: path.clone(), source })?;
        Scenario::from_json(&root, Some(path))
    }

    /// Load and validate one scenario file.
    pub fn load(path: &Path) -> Result<Scenario, SpecError> {
        let text = std::fs::read_to_string(path)
            .map_err(|source| SpecError::Io { path: path.to_path_buf(), source })?;
        Scenario::parse(&text, path)
    }

    /// Build a scenario from a parsed JSON value.
    pub fn from_json(root: &Json, path: Option<PathBuf>) -> Result<Scenario, SpecError> {
        const SCENARIO_KEYS: &[&str] = &[
            "name",
            "description",
            "dataset",
            "config",
            "tenancy",
            "threads",
            "legs",
            "invariants",
        ];
        let map = as_obj(root, "scenario")?;
        check_keys(map, "scenario", SCENARIO_KEYS)?;

        let name = req_str(map, "scenario", "name")?.to_string();
        let description = opt_str(map, "scenario", "description")?.unwrap_or_default().to_string();
        let dataset = parse_dataset(map.get("dataset"), "dataset")?;
        let base = match map.get("config") {
            Some(v) => parse_run(as_obj(v, "config")?, "config", &RunSpec::default())?,
            None => RunSpec::default(),
        };
        let tenancy = match opt_str(map, "scenario", "tenancy")? {
            None | Some("sequential") => Tenancy::Sequential,
            Some("concurrent") => Tenancy::Concurrent,
            Some(other) => {
                return Err(bad("scenario", "tenancy", other, "\"sequential\" or \"concurrent\""))
            }
        };
        let threads = opt_usize(map, "scenario", "threads")?.unwrap_or(2).max(1);

        let legs_json = map
            .get("legs")
            .ok_or_else(|| SpecError::MissingField { section: "scenario".into(), field: "legs" })?;
        let Json::Arr(leg_items) = legs_json else {
            return Err(SpecError::WrongShape { section: "legs".into(), expected: "an array" });
        };
        let mut legs = Vec::with_capacity(leg_items.len());
        for (i, item) in leg_items.iter().enumerate() {
            legs.push(parse_leg(item, &format!("legs[{i}]"), &base)?);
        }

        let inv_json = map.get("invariants").ok_or_else(|| SpecError::MissingField {
            section: "scenario".into(),
            field: "invariants",
        })?;
        let Json::Arr(inv_items) = inv_json else {
            return Err(SpecError::WrongShape {
                section: "invariants".into(),
                expected: "an array",
            });
        };
        let mut invariants = Vec::with_capacity(inv_items.len());
        for (i, item) in inv_items.iter().enumerate() {
            invariants.push(parse_invariant(item, &format!("invariants[{i}]"))?);
        }

        let scenario =
            Scenario { name, description, path, dataset, base, tenancy, threads, legs, invariants };
        scenario.validate()?;
        Ok(scenario)
    }

    /// Cross-field validation: leg-name uniqueness, invariant references,
    /// and the combination rules that make specs executable.
    fn validate(&self) -> Result<(), SpecError> {
        if self.legs.is_empty() {
            return Err(SpecError::NoLegs { scenario: self.name.clone() });
        }
        if self.invariants.is_empty() {
            return Err(SpecError::NoInvariants { scenario: self.name.clone() });
        }
        let mut seen = std::collections::BTreeSet::new();
        for leg in &self.legs {
            if !seen.insert(leg.name.as_str()) {
                return Err(SpecError::DuplicateLeg { name: leg.name.clone() });
            }
            if leg.run.staleness > 0 && leg.run.sweep == SweepMode::Lockstep {
                return Err(SpecError::StalenessOnLockstep {
                    leg: leg.name.clone(),
                    staleness: leg.run.staleness,
                });
            }
            if leg.fault_block.is_some() {
                if leg.resume && leg.checkpoint_every == 0 {
                    return Err(SpecError::FaultWithoutCheckpoint { leg: leg.name.clone() });
                }
                if self.tenancy == Tenancy::Concurrent {
                    return Err(SpecError::FaultInConcurrent { leg: leg.name.clone() });
                }
            }
            if let Some(from) = &leg.update_from {
                if self.tenancy == Tenancy::Concurrent {
                    return Err(SpecError::UpdateInConcurrent { leg: leg.name.clone() });
                }
                for (knob, set) in
                    [("fault_block", leg.fault_block.is_some()), ("store", leg.store)]
                {
                    if set {
                        return Err(SpecError::UpdateConflict {
                            leg: leg.name.clone(),
                            conflict: knob,
                        });
                    }
                }
                // the prior leg must run earlier, and be an ordinary
                // training run — an update or fault leg's checkpoints
                // would not be a complete, uninterrupted prior
                let earlier_ok = self
                    .legs
                    .iter()
                    .take_while(|l| l.name != leg.name)
                    .any(|l| {
                        l.name == *from && l.update_from.is_none() && l.fault_block.is_none()
                    });
                if !earlier_ok {
                    return Err(SpecError::UpdateFromNotEarlier {
                        leg: leg.name.clone(),
                        from: from.clone(),
                    });
                }
            } else if leg.delta_frac != 0.0 {
                return Err(SpecError::DeltaWithoutUpdate { leg: leg.name.clone() });
            }
        }
        for inv in &self.invariants {
            for leg in inv.legs() {
                if !seen.contains(leg) {
                    return Err(SpecError::UnknownLeg {
                        invariant: inv.label(),
                        leg: leg.to_string(),
                    });
                }
            }
        }
        Ok(())
    }

    /// The path the CLI should name in re-run hints.
    pub fn display_path(&self) -> String {
        match &self.path {
            Some(p) => p.display().to_string(),
            None => format!("<{}>", self.name),
        }
    }
}

/// Load every scenario from `path`: a single `.json` file, or a
/// directory swept non-recursively in sorted filename order. An empty
/// directory is a typed [`SpecError::NoScenarios`] — a sweep that runs
/// nothing must not look green.
pub fn load_path(path: &Path) -> Result<Vec<Scenario>, SpecError> {
    let meta = std::fs::metadata(path)
        .map_err(|source| SpecError::Io { path: path.to_path_buf(), source })?;
    if !meta.is_dir() {
        return Ok(vec![Scenario::load(path)?]);
    }
    let entries = std::fs::read_dir(path)
        .map_err(|source| SpecError::Io { path: path.to_path_buf(), source })?;
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "json"))
        .collect();
    files.sort();
    if files.is_empty() {
        return Err(SpecError::NoScenarios { path: path.to_path_buf() });
    }
    files.iter().map(|f| Scenario::load(f)).collect()
}

// ---------------------------------------------------------------------------
// section parsers

fn parse_dataset(v: Option<&Json>, section: &str) -> Result<DatasetSpec, SpecError> {
    const KEYS: &[&str] = &["profile", "scale", "seed", "k", "test_frac"];
    let empty = BTreeMap::new();
    let map = match v {
        Some(v) => as_obj(v, section)?,
        None => &empty,
    };
    check_keys(map, section, KEYS)?;
    let profile = opt_str(map, section, "profile")?.unwrap_or("movielens").to_string();
    if DatasetProfile::by_name(&profile).is_none() {
        return Err(bad(section, "profile", &profile, "movielens | netflix | yahoo | amazon"));
    }
    let scale = opt_f64(map, section, "scale")?.unwrap_or(0.002);
    if !(scale > 0.0 && scale.is_finite()) {
        return Err(bad(section, "scale", &scale.to_string(), "a positive finite number"));
    }
    let test_frac = opt_f64(map, section, "test_frac")?.unwrap_or(0.2);
    if !(0.0..1.0).contains(&test_frac) {
        return Err(bad(section, "test_frac", &test_frac.to_string(), "a fraction in [0, 1)"));
    }
    Ok(DatasetSpec {
        profile,
        scale,
        seed: opt_u64(map, section, "seed")?.unwrap_or(42),
        k: opt_usize(map, section, "k")?,
        test_frac,
    })
}

/// Keys shared by the `config` section and per-leg overrides.
const RUN_KEYS: &[&str] = &[
    "grid",
    "burnin",
    "samples",
    "seed",
    "workers",
    "tau",
    "sweep",
    "chunk_rows",
    "staleness",
    "scheduler",
    "priority",
    "max_in_flight",
];

fn parse_run(
    map: &BTreeMap<String, Json>,
    section: &str,
    base: &RunSpec,
) -> Result<RunSpec, SpecError> {
    let mut run = base.clone();
    if let Some(g) = opt_str(map, section, "grid")? {
        run.grid = parse_grid(g).ok_or_else(|| bad(section, "grid", g, "\"IxJ\" like \"3x3\""))?;
    }
    if let Some(v) = opt_usize(map, section, "burnin")? {
        run.burnin = v;
    }
    if let Some(v) = opt_usize(map, section, "samples")? {
        run.samples = v;
    }
    if let Some(v) = opt_u64(map, section, "seed")? {
        run.seed = v;
    }
    if let Some(v) = opt_usize(map, section, "workers")? {
        run.workers = v;
    }
    if let Some(v) = opt_f64(map, section, "tau")? {
        run.tau = Some(v);
    }
    if let Some(v) = opt_str(map, section, "sweep")? {
        run.sweep = match v {
            "lockstep" => SweepMode::Lockstep,
            "pipelined" => SweepMode::Pipelined,
            other => return Err(bad(section, "sweep", other, "\"lockstep\" or \"pipelined\"")),
        };
    }
    if let Some(v) = opt_usize(map, section, "chunk_rows")? {
        run.chunk_rows = v;
    }
    if let Some(v) = opt_usize(map, section, "staleness")? {
        run.staleness = v;
    }
    if let Some(v) = opt_str(map, section, "scheduler")? {
        run.scheduler = match v {
            "dag" => SchedulerMode::Dag,
            "barrier" => SchedulerMode::Barrier,
            other => return Err(bad(section, "scheduler", other, "\"dag\" or \"barrier\"")),
        };
    }
    if let Some(v) = opt_str(map, section, "priority")? {
        run.priority = v
            .parse::<Priority>()
            .map_err(|_| bad(section, "priority", v, "\"low\", \"normal\", or \"high\""))?;
    }
    if let Some(v) = opt_usize(map, section, "max_in_flight")? {
        run.max_in_flight = v;
    }
    Ok(run)
}

fn parse_leg(v: &Json, section: &str, base: &RunSpec) -> Result<LegSpec, SpecError> {
    const LEG_ONLY: &[&str] = &[
        "name",
        "store",
        "cache_bytes",
        "fault_block",
        "resume",
        "checkpoint_every",
        "update_from",
        "delta_frac",
    ];
    let map = as_obj(v, section)?;
    let allowed: Vec<&'static str> = LEG_ONLY.iter().chain(RUN_KEYS).copied().collect();
    check_keys(map, section, &allowed)?;
    let delta_frac = opt_f64(map, section, "delta_frac")?.unwrap_or(0.0);
    if !(0.0..=1.0).contains(&delta_frac) {
        return Err(bad(section, "delta_frac", &delta_frac.to_string(), "a fraction in [0, 1]"));
    }
    Ok(LegSpec {
        name: req_str(map, section, "name")?.to_string(),
        run: parse_run(map, section, base)?,
        store: opt_bool(map, section, "store")?.unwrap_or(false),
        cache_bytes: opt_u64(map, section, "cache_bytes")?.unwrap_or(0),
        fault_block: opt_usize(map, section, "fault_block")?,
        resume: opt_bool(map, section, "resume")?.unwrap_or(true),
        checkpoint_every: opt_usize(map, section, "checkpoint_every")?.unwrap_or(0),
        update_from: opt_str(map, section, "update_from")?.map(str::to_string),
        delta_frac,
    })
}

fn parse_invariant(v: &Json, section: &str) -> Result<Invariant, SpecError> {
    let map = as_obj(v, section)?;
    let check = req_str(map, section, "check")?;
    let inv = match check {
        "rmse_max" => {
            check_keys(map, section, &["check", "leg", "max"])?;
            Invariant::RmseMax {
                leg: req_str(map, section, "leg")?.to_string(),
                max: req_f64(map, section, "max")?,
            }
        }
        "bitwise_equal" => {
            check_keys(map, section, &["check", "legs"])?;
            let legs = req_str_list(map, section, "legs")?;
            if legs.len() < 2 {
                return Err(bad(section, "legs", &format!("{legs:?}"), "at least two leg names"));
            }
            Invariant::BitwiseEqual { legs }
        }
        "max_queue_wait_secs" => {
            check_keys(map, section, &["check", "leg", "max"])?;
            Invariant::MaxQueueWaitSecs {
                leg: req_str(map, section, "leg")?.to_string(),
                max: req_f64(map, section, "max")?,
            }
        }
        "min_evictions" => {
            check_keys(map, section, &["check", "leg", "min"])?;
            Invariant::MinEvictions {
                leg: req_str(map, section, "leg")?.to_string(),
                min: req_f64(map, section, "min")? as u64,
            }
        }
        "expect_outcome" => {
            check_keys(map, section, &["check", "leg", "outcome"])?;
            let outcome = match req_str(map, section, "outcome")? {
                "completed" => ExpectedOutcome::Completed,
                "failed" => ExpectedOutcome::Failed,
                other => return Err(bad(section, "outcome", other, "\"completed\" or \"failed\"")),
            };
            Invariant::ExpectOutcome { leg: req_str(map, section, "leg")?.to_string(), outcome }
        }
        "resume_bitwise" => {
            check_keys(map, section, &["check", "resumed", "reference"])?;
            Invariant::ResumeBitwise {
                resumed: req_str(map, section, "resumed")?.to_string(),
                reference: req_str(map, section, "reference")?.to_string(),
            }
        }
        "finish_before" => {
            check_keys(map, section, &["check", "first", "then"])?;
            Invariant::FinishBefore {
                first: req_str(map, section, "first")?.to_string(),
                then: req_str(map, section, "then")?.to_string(),
            }
        }
        "max_blocks_resampled" => {
            check_keys(map, section, &["check", "leg", "max"])?;
            let max = req_f64(map, section, "max")?;
            if !(max >= 0.0 && max.fract() == 0.0) {
                return Err(bad(section, "max", &max.to_string(), "a non-negative integer"));
            }
            Invariant::MaxBlocksResampled {
                leg: req_str(map, section, "leg")?.to_string(),
                max: max as usize,
            }
        }
        other => {
            return Err(bad(
                section,
                "check",
                other,
                "rmse_max | bitwise_equal | max_queue_wait_secs | min_evictions | \
                 expect_outcome | resume_bitwise | finish_before | max_blocks_resampled",
            ))
        }
    };
    Ok(inv)
}

// ---------------------------------------------------------------------------
// JSON field helpers (strict: wrong types are BadValue, never defaults)

fn as_obj<'a>(v: &'a Json, section: &str) -> Result<&'a BTreeMap<String, Json>, SpecError> {
    match v {
        Json::Obj(m) => Ok(m),
        _ => Err(SpecError::WrongShape { section: section.into(), expected: "an object" }),
    }
}

fn check_keys(
    map: &BTreeMap<String, Json>,
    section: &str,
    allowed: &[&'static str],
) -> Result<(), SpecError> {
    for key in map.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(SpecError::UnknownKey {
                section: section.into(),
                key: key.clone(),
                known: allowed.to_vec(),
            });
        }
    }
    Ok(())
}

fn bad(section: &str, field: &str, got: &str, expected: &str) -> SpecError {
    SpecError::BadValue {
        section: section.into(),
        field: field.into(),
        got: got.into(),
        expected: expected.into(),
    }
}

fn opt_str<'a>(
    map: &'a BTreeMap<String, Json>,
    section: &str,
    field: &str,
) -> Result<Option<&'a str>, SpecError> {
    match map.get(field) {
        None => Ok(None),
        Some(Json::Str(s)) => Ok(Some(s)),
        Some(other) => Err(bad(section, field, &json::to_string(other), "a string")),
    }
}

fn req_str<'a>(
    map: &'a BTreeMap<String, Json>,
    section: &str,
    field: &'static str,
) -> Result<&'a str, SpecError> {
    opt_str(map, section, field)?
        .ok_or_else(|| SpecError::MissingField { section: section.into(), field })
}

fn opt_f64(
    map: &BTreeMap<String, Json>,
    section: &str,
    field: &str,
) -> Result<Option<f64>, SpecError> {
    match map.get(field) {
        None => Ok(None),
        Some(Json::Num(n)) => Ok(Some(*n)),
        Some(other) => Err(bad(section, field, &json::to_string(other), "a number")),
    }
}

fn req_f64(
    map: &BTreeMap<String, Json>,
    section: &str,
    field: &'static str,
) -> Result<f64, SpecError> {
    opt_f64(map, section, field)?
        .ok_or_else(|| SpecError::MissingField { section: section.into(), field })
}

fn opt_usize(
    map: &BTreeMap<String, Json>,
    section: &str,
    field: &str,
) -> Result<Option<usize>, SpecError> {
    match opt_f64(map, section, field)? {
        None => Ok(None),
        Some(n) if n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 52) as f64 => Ok(Some(n as usize)),
        Some(n) => Err(bad(section, field, &n.to_string(), "a non-negative integer")),
    }
}

fn opt_u64(
    map: &BTreeMap<String, Json>,
    section: &str,
    field: &str,
) -> Result<Option<u64>, SpecError> {
    Ok(opt_usize(map, section, field)?.map(|n| n as u64))
}

fn opt_bool(
    map: &BTreeMap<String, Json>,
    section: &str,
    field: &str,
) -> Result<Option<bool>, SpecError> {
    match map.get(field) {
        None => Ok(None),
        Some(Json::Bool(b)) => Ok(Some(*b)),
        Some(other) => Err(bad(section, field, &json::to_string(other), "a boolean")),
    }
}

fn req_str_list(
    map: &BTreeMap<String, Json>,
    section: &str,
    field: &'static str,
) -> Result<Vec<String>, SpecError> {
    match map.get(field) {
        None => Err(SpecError::MissingField { section: section.into(), field }),
        Some(Json::Arr(items)) => items
            .iter()
            .map(|v| match v {
                Json::Str(s) => Ok(s.clone()),
                other => Err(bad(section, field, &json::to_string(other), "an array of strings")),
            })
            .collect(),
        Some(other) => Err(bad(section, field, &json::to_string(other), "an array of strings")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(extra_leg: &str, invariant: &str) -> String {
        format!(
            r#"{{
              "name": "t", "description": "d",
              "dataset": {{"profile": "movielens", "scale": 0.001, "seed": 1}},
              "config": {{"grid": "2x2", "burnin": 2, "samples": 4, "seed": 1}},
              "legs": [{{"name": "a"}}{extra_leg}],
              "invariants": [{invariant}]
            }}"#
        )
    }

    #[test]
    fn parses_minimal_scenario() {
        let s = Scenario::parse(
            &minimal("", r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#),
            "<test>",
        )
        .unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.legs.len(), 1);
        assert_eq!(s.base.grid, (2, 2));
        assert_eq!(s.tenancy, Tenancy::Sequential);
        assert!(matches!(
            s.invariants[0],
            Invariant::RmseMax { ref leg, max } if leg == "a" && max == 2.0
        ));
    }

    #[test]
    fn leg_overrides_inherit_base() {
        let s = Scenario::parse(
            &minimal(
                r#", {"name": "b", "sweep": "pipelined", "staleness": 1, "chunk_rows": 32}"#,
                r#"{"check": "bitwise_equal", "legs": ["a", "b"]}"#,
            ),
            "<test>",
        )
        .unwrap();
        let b = &s.legs[1];
        assert_eq!(b.run.sweep, SweepMode::Pipelined);
        assert_eq!(b.run.staleness, 1);
        assert_eq!(b.run.chunk_rows, 32);
        // inherited, not defaulted
        assert_eq!(b.run.grid, (2, 2));
        assert_eq!(b.run.burnin, 2);
    }

    #[test]
    fn malformed_json_is_typed() {
        let err = Scenario::parse("{ not json", "<test>").unwrap_err();
        assert!(matches!(err, SpecError::Json { .. }), "{err}");
    }

    #[test]
    fn unknown_keys_are_rejected_everywhere() {
        for (text, key) in [
            (minimal("", r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#)
                .replace("\"name\": \"t\"", "\"name\": \"t\", \"oops\": 1"), "oops"),
            (minimal(r#", {"name": "b", "cache_byte": 1}"#,
                r#"{"check": "bitwise_equal", "legs": ["a", "b"]}"#), "cache_byte"),
            (minimal("", r#"{"check": "rmse_max", "leg": "a", "max": 2.0, "mx": 1}"#), "mx"),
        ] {
            let err = Scenario::parse(&text, "<test>").unwrap_err();
            match err {
                SpecError::UnknownKey { key: k, .. } => assert_eq!(k, key),
                other => panic!("expected UnknownKey({key}), got {other}"),
            }
        }
    }

    #[test]
    fn unknown_invariant_name_is_typed() {
        let err = Scenario::parse(
            &minimal("", r#"{"check": "rmse_min", "leg": "a", "max": 2.0}"#),
            "<test>",
        )
        .unwrap_err();
        assert!(
            matches!(err, SpecError::BadValue { ref field, .. } if field == "check"),
            "{err}"
        );
    }

    #[test]
    fn staleness_on_lockstep_is_typed() {
        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "staleness": 2}"#,
                r#"{"check": "bitwise_equal", "legs": ["a", "b"]}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::StalenessOnLockstep { staleness: 2, .. }), "{err}");
    }

    #[test]
    fn fault_without_checkpointing_is_typed() {
        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "fault_block": 2}"#,
                r#"{"check": "expect_outcome", "leg": "b", "outcome": "failed"}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::FaultWithoutCheckpoint { .. }), "{err}");
        // resume: false is the escape hatch — the leg asserts the failure
        Scenario::parse(
            &minimal(
                r#", {"name": "b", "fault_block": 2, "resume": false}"#,
                r#"{"check": "expect_outcome", "leg": "b", "outcome": "failed"}"#,
            ),
            "<test>",
        )
        .unwrap();
    }

    #[test]
    fn invariant_referencing_unknown_leg_is_typed() {
        let err = Scenario::parse(
            &minimal("", r#"{"check": "rmse_max", "leg": "ghost", "max": 2.0}"#),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::UnknownLeg { ref leg, .. } if leg == "ghost"), "{err}");
    }

    #[test]
    fn duplicate_and_empty_legs_are_typed() {
        let err = Scenario::parse(
            &minimal(r#", {"name": "a"}"#, r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::DuplicateLeg { .. }), "{err}");

        let text = r#"{"name": "t", "legs": [], "invariants": [{"check": "bitwise_equal", "legs": ["a", "b"]}]}"#;
        let err = Scenario::parse(text, "<test>").unwrap_err();
        assert!(matches!(err, SpecError::NoLegs { .. }), "{err}");
    }

    #[test]
    fn bad_enum_values_are_typed() {
        for (leg, field) in [
            (r#", {"name": "b", "sweep": "warp"}"#, "sweep"),
            (r#", {"name": "b", "scheduler": "ring"}"#, "scheduler"),
            (r#", {"name": "b", "priority": "urgent"}"#, "priority"),
            (r#", {"name": "b", "grid": "3by3"}"#, "grid"),
            (r#", {"name": "b", "burnin": -1}"#, "burnin"),
            (r#", {"name": "b", "store": "yes"}"#, "store"),
        ] {
            let err = Scenario::parse(
                &minimal(leg, r#"{"check": "bitwise_equal", "legs": ["a", "b"]}"#),
                "<test>",
            )
            .unwrap_err();
            assert!(
                matches!(err, SpecError::BadValue { field: ref f, .. } if f == field),
                "field {field}: {err}"
            );
        }
    }

    #[test]
    fn update_leg_parses_and_validates_ordering() {
        let s = Scenario::parse(
            &minimal(
                r#", {"name": "b", "update_from": "a", "delta_frac": 0.1}"#,
                r#"{"check": "max_blocks_resampled", "leg": "b", "max": 1}"#,
            ),
            "<test>",
        )
        .unwrap();
        assert_eq!(s.legs[1].update_from.as_deref(), Some("a"));
        assert_eq!(s.legs[1].delta_frac, 0.1);
        assert!(matches!(
            s.invariants[0],
            Invariant::MaxBlocksResampled { ref leg, max: 1 } if leg == "b"
        ));

        // forward reference: the prior leg has not run yet
        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "update_from": "c"}, {"name": "c"}"#,
                r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::UpdateFromNotEarlier { .. }), "{err}");

        // self reference is just as out of order
        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "update_from": "b"}"#,
                r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::UpdateFromNotEarlier { .. }), "{err}");
    }

    #[test]
    fn update_leg_conflicts_are_typed() {
        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "update_from": "a", "store": true}"#,
                r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(
            matches!(err, SpecError::UpdateConflict { conflict: "store", .. }),
            "{err}"
        );

        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "delta_frac": 0.5}"#,
                r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(matches!(err, SpecError::DeltaWithoutUpdate { .. }), "{err}");

        let err = Scenario::parse(
            &minimal(
                r#", {"name": "b", "update_from": "a", "delta_frac": 1.5}"#,
                r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
            ),
            "<test>",
        )
        .unwrap_err();
        assert!(
            matches!(err, SpecError::BadValue { ref field, .. } if field == "delta_frac"),
            "{err}"
        );
    }

    #[test]
    fn update_in_concurrent_is_typed() {
        let text = minimal(
            r#", {"name": "b", "update_from": "a"}"#,
            r#"{"check": "rmse_max", "leg": "a", "max": 2.0}"#,
        )
        .replace("\"name\": \"t\"", "\"name\": \"t\", \"tenancy\": \"concurrent\"");
        let err = Scenario::parse(&text, "<test>").unwrap_err();
        assert!(matches!(err, SpecError::UpdateInConcurrent { .. }), "{err}");
    }

    #[test]
    fn max_blocks_resampled_rejects_fractional_max() {
        let err = Scenario::parse(
            &minimal("", r#"{"check": "max_blocks_resampled", "leg": "a", "max": 0.5}"#),
            "<test>",
        )
        .unwrap_err();
        assert!(
            matches!(err, SpecError::BadValue { ref field, .. } if field == "max"),
            "{err}"
        );
    }

    #[test]
    fn load_path_on_missing_file_is_io_error() {
        let err = load_path(Path::new("/definitely/missing/scenario.json")).unwrap_err();
        assert!(matches!(err, SpecError::Io { .. }), "{err}");
    }
}
