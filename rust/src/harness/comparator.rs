//! Invariant evaluation: turn a scenario's declarative checks into
//! pass/fail verdicts over the executor's [`LegResult`]s.
//!
//! Each [`Invariant`] becomes one [`CheckResult`]; the detail string
//! always carries the observed numbers so a failure is diagnosable from
//! the report alone. Bitwise equality compares the full posterior —
//! per-row means *and* precisions on both sides, plus the global mean —
//! with exact `f64` equality, the same bar the repo's Rust tests hold
//! store/resident and pipelined/lockstep equivalences to.

use crate::posterior::PosteriorModel;

use super::executor::{LegOutcome, LegResult, ScenarioRun};
use super::spec::{ExpectedOutcome, Invariant, Scenario};

/// One evaluated invariant.
#[derive(Debug, Clone)]
pub struct CheckResult {
    /// The invariant's compact label (e.g. `bitwise_equal(a, b)`).
    pub invariant: String,
    /// Whether it held.
    pub passed: bool,
    /// Observed values (or what was missing) — the failure diagnosis.
    pub detail: String,
}

impl CheckResult {
    fn pass(invariant: String, detail: String) -> CheckResult {
        CheckResult { invariant, passed: true, detail }
    }

    fn fail(invariant: String, detail: String) -> CheckResult {
        CheckResult { invariant, passed: false, detail }
    }
}

/// Evaluate every invariant of `scn` against the executed `run`.
pub fn evaluate(scn: &Scenario, run: &ScenarioRun) -> Vec<CheckResult> {
    scn.invariants.iter().map(|inv| evaluate_one(inv, run)).collect()
}

fn evaluate_one(inv: &Invariant, run: &ScenarioRun) -> CheckResult {
    let label = inv.label();
    match inv {
        Invariant::RmseMax { leg, max } => match completed(run, leg) {
            Err(detail) => CheckResult::fail(label, detail),
            Ok(result) => match result.rmse {
                Some(rmse) if rmse.is_finite() && rmse <= *max => {
                    CheckResult::pass(label, format!("rmse {rmse:.4} <= {max}"))
                }
                Some(rmse) => CheckResult::fail(label, format!("rmse {rmse:.4} > {max}")),
                None => CheckResult::fail(label, format!("leg '{leg}' produced no model")),
            },
        },
        Invariant::BitwiseEqual { legs } => bitwise_equal(run, legs, label),
        Invariant::MaxQueueWaitSecs { leg, max } => match completed(run, leg) {
            Err(detail) => CheckResult::fail(label, detail),
            Ok(result) => {
                let wait = result.stats.map(|s| s.queue_wait_secs).unwrap_or(f64::INFINITY);
                if wait <= *max {
                    CheckResult::pass(label, format!("queue wait {wait:.3}s <= {max}s"))
                } else {
                    CheckResult::fail(label, format!("queue wait {wait:.3}s > {max}s"))
                }
            }
        },
        Invariant::MinEvictions { leg, min } => match completed(run, leg) {
            Err(detail) => CheckResult::fail(label, detail),
            Ok(result) => {
                let evictions = result.stats.map(|s| s.shard_evictions).unwrap_or(0);
                if evictions >= *min {
                    CheckResult::pass(label, format!("{evictions} evictions >= {min}"))
                } else {
                    CheckResult::fail(
                        label,
                        format!("{evictions} evictions < {min} — cache budget never bound"),
                    )
                }
            }
        },
        Invariant::ExpectOutcome { leg, outcome } => match run.leg(leg) {
            None => CheckResult::fail(label, format!("leg '{leg}' was not executed")),
            Some(result) => {
                let matches = matches!(
                    (outcome, result.outcome),
                    (ExpectedOutcome::Completed, LegOutcome::Completed)
                        | (ExpectedOutcome::Failed, LegOutcome::Failed)
                );
                let observed = match &result.error {
                    Some(e) => format!("{} ({e})", result.outcome),
                    None => result.outcome.to_string(),
                };
                if matches {
                    CheckResult::pass(label, format!("leg '{leg}' ended {observed}"))
                } else {
                    CheckResult::fail(
                        label,
                        format!("leg '{leg}' ended {observed}, expected {outcome}"),
                    )
                }
            }
        },
        Invariant::ResumeBitwise { resumed, reference } => {
            let restored = match completed(run, resumed) {
                Err(detail) => return CheckResult::fail(label, detail),
                Ok(result) => result.blocks_restored,
            };
            if restored == 0 {
                return CheckResult::fail(
                    label,
                    format!("leg '{resumed}' restored 0 blocks — it never actually resumed"),
                );
            }
            let bitwise = bitwise_equal(run, &[resumed.clone(), reference.clone()], label.clone());
            if bitwise.passed {
                CheckResult::pass(label, format!("{restored} blocks restored; {}", bitwise.detail))
            } else {
                bitwise
            }
        }
        Invariant::MaxBlocksResampled { leg, max } => match completed(run, leg) {
            Err(detail) => CheckResult::fail(label, detail),
            Ok(result) => match result.stats {
                None => CheckResult::fail(label, format!("leg '{leg}' recorded no stats")),
                Some(stats) => {
                    if stats.blocks <= *max {
                        CheckResult::pass(
                            label,
                            format!(
                                "{} blocks re-sampled <= {max} ({} passed through clean)",
                                stats.blocks, stats.blocks_skipped_clean
                            ),
                        )
                    } else {
                        CheckResult::fail(
                            label,
                            format!(
                                "{} blocks re-sampled > {max} — the update touched \
                                 more than its dirty set",
                                stats.blocks
                            ),
                        )
                    }
                }
            },
        },
        Invariant::FinishBefore { first, then } => {
            let (a, b) = match (run.leg(first), run.leg(then)) {
                (Some(a), Some(b)) => (a, b),
                _ => return CheckResult::fail(label, "a referenced leg was not executed".into()),
            };
            if a.finished_rank < b.finished_rank {
                CheckResult::pass(
                    label,
                    format!(
                        "'{first}' finished #{} before '{then}' #{}",
                        a.finished_rank + 1,
                        b.finished_rank + 1
                    ),
                )
            } else {
                CheckResult::fail(
                    label,
                    format!(
                        "'{first}' finished #{}, '{then}' finished #{}",
                        a.finished_rank + 1,
                        b.finished_rank + 1
                    ),
                )
            }
        }
    }
}

/// The leg's result if it completed, else a failure detail.
fn completed<'a>(run: &'a ScenarioRun, leg: &str) -> Result<&'a LegResult, String> {
    match run.leg(leg) {
        None => Err(format!("leg '{leg}' was not executed")),
        Some(r) if r.outcome == LegOutcome::Completed => Ok(r),
        Some(r) => Err(format!(
            "leg '{leg}' did not complete: {} ({})",
            r.outcome,
            r.error.as_deref().unwrap_or("no detail")
        )),
    }
}

fn bitwise_equal(run: &ScenarioRun, legs: &[String], label: String) -> CheckResult {
    let mut models: Vec<(&str, &PosteriorModel)> = Vec::with_capacity(legs.len());
    for leg in legs {
        match completed(run, leg) {
            Err(detail) => return CheckResult::fail(label, detail),
            Ok(result) => match &result.model {
                Some(m) => models.push((leg, m)),
                None => {
                    return CheckResult::fail(label, format!("leg '{leg}' produced no model"))
                }
            },
        }
    }
    let (base_name, base) = models[0];
    for (name, model) in &models[1..] {
        if let Some(diff) = first_difference(base, model) {
            return CheckResult::fail(
                label,
                format!("'{base_name}' and '{name}' diverge: {diff}"),
            );
        }
    }
    CheckResult::pass(label, format!("{} models bit-for-bit identical", models.len()))
}

/// Exact posterior comparison; returns a description of the first
/// mismatch, or `None` when the models are bit-for-bit identical.
fn first_difference(a: &PosteriorModel, b: &PosteriorModel) -> Option<String> {
    if a.k != b.k {
        return Some(format!("k {} vs {}", a.k, b.k));
    }
    if a.global_mean.to_bits() != b.global_mean.to_bits() {
        return Some(format!("global_mean {} vs {}", a.global_mean, b.global_mean));
    }
    for (side, ga, gb) in [("u", &a.u_post, &b.u_post), ("v", &a.v_post, &b.v_post)] {
        if ga.n != gb.n {
            return Some(format!("{side}_post rows {} vs {}", ga.n, gb.n));
        }
        for (field, xa, xb) in [("mean", &ga.mean, &gb.mean), ("prec", &ga.prec, &gb.prec)] {
            if let Some(i) = (0..xa.len().max(xb.len()))
                .find(|&i| xa.get(i).map(|v| v.to_bits()) != xb.get(i).map(|v| v.to_bits()))
            {
                return Some(format!(
                    "{side}_post.{field}[{i}]: {:?} vs {:?}",
                    xa.get(i),
                    xb.get(i)
                ));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::posterior::gaussian::RowGaussians;

    fn model(shift: f64) -> PosteriorModel {
        let g = RowGaussians {
            n: 2,
            k: 2,
            mean: vec![0.1 + shift, 0.2, 0.3, 0.4],
            prec: vec![1.0; 2 * 2 * 2],
        };
        PosteriorModel::new(g.clone(), g, 3.5)
    }

    fn completed_leg(name: &str, m: PosteriorModel) -> LegResult {
        LegResult {
            name: name.into(),
            outcome: LegOutcome::Completed,
            error: None,
            model: Some(m),
            stats: None,
            rmse: Some(1.0),
            blocks_restored: 0,
            secs: 0.0,
            finished_rank: 0,
        }
    }

    #[test]
    fn bitwise_detects_single_ulp() {
        let run = ScenarioRun {
            name: "t".into(),
            path: "<t>".into(),
            legs: vec![
                completed_leg("a", model(0.0)),
                completed_leg("b", model(0.0)),
                completed_leg("c", model(f64::EPSILON)),
            ],
            secs: 0.0,
        };
        let same = bitwise_equal(&run, &["a".into(), "b".into()], "x".into());
        assert!(same.passed, "{}", same.detail);
        let diff = bitwise_equal(&run, &["a".into(), "c".into()], "x".into());
        assert!(!diff.passed);
        assert!(diff.detail.contains("u_post.mean[0]"), "{}", diff.detail);
    }

    #[test]
    fn max_blocks_resampled_bounds_sampled_blocks() {
        use crate::coordinator::trainer::RunStats;
        let mut leg = completed_leg("a", model(0.0));
        leg.stats =
            Some(RunStats { blocks: 1, blocks_skipped_clean: 3, ..RunStats::default() });
        let run = ScenarioRun {
            name: "t".into(),
            path: "<t>".into(),
            legs: vec![leg],
            secs: 0.0,
        };
        let pass =
            evaluate_one(&Invariant::MaxBlocksResampled { leg: "a".into(), max: 1 }, &run);
        assert!(pass.passed, "{}", pass.detail);
        assert!(pass.detail.contains("3 passed through clean"), "{}", pass.detail);
        let fail =
            evaluate_one(&Invariant::MaxBlocksResampled { leg: "a".into(), max: 0 }, &run);
        assert!(!fail.passed);
        assert!(fail.detail.contains("1 blocks re-sampled > 0"), "{}", fail.detail);
    }

    #[test]
    fn incomplete_leg_fails_not_panics() {
        let run = ScenarioRun {
            name: "t".into(),
            path: "<t>".into(),
            legs: vec![LegResult {
                name: "a".into(),
                outcome: LegOutcome::Failed,
                error: Some("boom".into()),
                model: None,
                stats: None,
                rmse: None,
                blocks_restored: 0,
                secs: 0.0,
                finished_rank: 0,
            }],
            secs: 0.0,
        };
        let r = evaluate_one(&Invariant::RmseMax { leg: "a".into(), max: 1.0 }, &run);
        assert!(!r.passed);
        assert!(r.detail.contains("boom"), "{}", r.detail);
    }
}
