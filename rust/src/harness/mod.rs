//! Declarative scenario harness: data-driven end-to-end specs with
//! invariant checking (`bmf-pp scenario`).
//!
//! A scenario is a JSON file describing a complete exercise of the
//! training stack — dataset, grid, sweep/scheduler modes, store-backed
//! legs, fault plans, multi-tenant job mixes — plus the invariants the
//! runs must satisfy (RMSE bounds, bitwise-equal pairs, queue-wait
//! bounds, eviction floors, expected outcomes, crash→resume
//! equivalence). The pipeline is four small modules:
//!
//! ```text
//! spec.rs        JSON file ──parse+validate──▶ Scenario   (typed SpecError on any defect)
//! executor.rs    Scenario ──Engine runs─────▶ ScenarioRun (per-leg models + RunStats)
//! comparator.rs  invariants × ScenarioRun ──▶ CheckResult verdicts
//! reporter.rs    verdicts ──────────────────▶ human table + machine JSON report
//! ```
//!
//! [`run_and_check`] strings them together for one scenario; the CLI
//! sweeps a directory of specs and exits non-zero if any invariant
//! fails. New workloads become data files under `scenarios/`, not new
//! Rust tests.

pub mod comparator;
pub mod executor;
pub mod reporter;
pub mod spec;

pub use comparator::{evaluate, CheckResult};
pub use executor::{run_scenario, LegOutcome, LegResult, ScenarioRun};
pub use reporter::{render_human, render_summary, to_json, ScenarioReport};
pub use spec::{load_path, Invariant, LegSpec, RunSpec, Scenario, SpecError, Tenancy};

/// Execute one scenario and evaluate its invariants.
pub fn run_and_check(scn: &Scenario) -> anyhow::Result<ScenarioReport> {
    let run = run_scenario(scn)?;
    let checks = evaluate(scn, &run);
    Ok(ScenarioReport { run, checks })
}
