//! Figures 4 & 5: strong scaling of D-BMF+PP on all four datasets —
//! wall-clock vs node count, one series per block grid, Pareto points
//! marked. Runs on the discrete-event cluster simulator calibrated against
//! this machine's measured sampler throughput (DESIGN.md §Substitutions).
//!
//! Shapes to reproduce from the paper:
//!   - Netflix/Yahoo (high K): near-linear scaling of small grids up to
//!     ~16-64 nodes; 1x1 flattens at the within-block cap.
//!   - Movielens/Amazon (K=10): 1x1 mostly flat (too little compute per
//!     comm); large grids win at high node counts (paper: 20x faster at
//!     2048 nodes with 32x32).
//!   - Run-time drops where node counts align with phase parallelism.
//!
//!     cargo bench --bench fig45_scaling

mod common;

use bmf_pp::cluster::calibrate::calibrate;
use bmf_pp::cluster::sim::{
    node_sweep, pareto_front, simulate_pp, simulate_pp_mode, uniform_block_nnz, ScheduleMode,
};
use bmf_pp::coordinator::backend::BlockBackend;
use bmf_pp::data::generator::DatasetProfile;
use bmf_pp::partition::Grid;
use bmf_pp::util::timer::fmt_hhmm;

fn main() {
    bmf_pp::util::logging::init();
    let backend = BlockBackend::Native;
    let sweeps = 28;
    let max_nodes = 16_384;

    let figures: &[(&str, &[&str], usize, &[(usize, usize)])] = &[
        ("FIGURE 4 (top): netflix", &["netflix"], 32, &[(1, 1), (2, 2), (4, 4), (16, 8), (32, 32)]),
        ("FIGURE 4 (bottom): yahoo", &["yahoo"], 32, &[(2, 2), (4, 4), (8, 8), (16, 16), (32, 32)]),
        (
            "FIGURE 5 (top): movielens",
            &["movielens"],
            8,
            &[(1, 1), (2, 2), (4, 4), (8, 8), (32, 32)],
        ),
        (
            "FIGURE 5 (bottom): amazon",
            &["amazon"],
            8,
            &[(1, 1), (4, 4), (8, 8), (16, 16), (32, 32)],
        ),
    ];

    let mut results = Vec::new();
    for (title, names, k, grids) in figures {
        let profile = DatasetProfile::by_name(names[0]).unwrap();
        let model = calibrate(&backend, (*k).min(32));
        println!(
            "\n{title} — {}x{} / {:.0}M ratings, K={k}",
            profile.paper_rows,
            profile.paper_cols,
            profile.paper_ratings as f64 / 1e6
        );
        common::hr();
        for &(gi, gj) in *grids {
            let grid = Grid::new(profile.paper_rows, profile.paper_cols, gi, gj);
            let nnz = uniform_block_nnz(&grid, profile.paper_ratings);
            let mut pts = Vec::new();
            let mut dag_gain_max = 1.0f64;
            for p in node_sweep(&grid, max_nodes) {
                let r = simulate_pp(&model, &grid, &nnz, *k, sweeps, sweeps, p);
                let rd =
                    simulate_pp_mode(&model, &grid, &nnz, *k, sweeps, sweeps, p, ScheduleMode::Dag);
                pts.push((p, r.total));
                dag_gain_max = dag_gain_max.max(r.total / rd.total.max(1e-12));
                results.push((format!("{}_{gi}x{gj}_n{p}_dag", names[0]), rd.total));
            }
            let front = pareto_front(&pts);
            print!("  {gi:>2}x{gj:<3} ");
            for (p, t) in pts.iter().filter(|(p, _)| p.is_power_of_two()) {
                let mark = if front.contains(&(*p, *t)) { "*" } else { "" };
                print!("{p}:{}{mark} ", fmt_hhmm(*t));
                results.push((format!("{}_{gi}x{gj}_n{p}", names[0]), *t));
            }
            print!(" [barrier-free gain up to {dag_gain_max:.2}x]");
            println!();
            // headline numbers: best speedup over 1-node 1x1
            if (gi, gj) == (1, 1) || gi * gj >= 64 {
                let t1 = pts.iter().find(|(p, _)| *p == 1).map(|(_, t)| *t);
                let tbest = front.last().map(|(_, t)| *t);
                if let (Some(a), Some(b)) = (t1, tbest) {
                    println!("        speedup at pareto end: {:.1}x", a / b);
                }
            }
        }
        common::hr();
    }
    // ---- barrier vs DAG on a skewed (imbalanced-nnz) grid ----
    // uniform grids barely separate the schedules (all blocks finish
    // together); with one 8x-dense phase-(b) block the barrier stalls
    println!("\nBARRIER vs DAG schedule, netflix 4x4 with one 8x-dense row block");
    common::hr();
    {
        let profile = DatasetProfile::by_name("netflix").unwrap();
        let model = calibrate(&BlockBackend::Native, 32);
        let grid = Grid::new(profile.paper_rows, profile.paper_cols, 4, 4);
        let mut nnz = uniform_block_nnz(&grid, profile.paper_ratings);
        nnz[1][0] *= 8;
        for p in [1usize, 6, 16, 64, 256] {
            let run = |mode: ScheduleMode| {
                simulate_pp_mode(&model, &grid, &nnz, 32, sweeps, sweeps, p, mode)
            };
            let bar = run(ScheduleMode::Barrier);
            let dag = run(ScheduleMode::Dag);
            println!(
                "  nodes={p:<5} barrier={:<10} dag={:<10} ({:.2}x)",
                fmt_hhmm(bar.total),
                fmt_hhmm(dag.total),
                bar.total / dag.total
            );
            results.push((format!("skew_barrier_n{p}"), bar.total));
            results.push((format!("skew_dag_n{p}"), dag.total));
        }
    }

    println!("\n(* = Pareto-optimal; node counts include phase-aligned points)");
    common::save_json("fig45.json", &results);
}
