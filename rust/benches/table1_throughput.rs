//! Table 1 (bottom rows): sampler throughput — rows/sec and ratings/sec —
//! for each dataset profile, on this machine, through the full D-BMF+PP
//! stack. Paper values (Hazel Hen node, K per dataset) printed alongside;
//! the comparison target is the *ordering and ratio structure* across
//! datasets, not absolute rates.
//!
//!     cargo bench --bench table1_throughput

mod common;

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{Engine, SweepMode, TrainConfig};
use bmf_pp::data::stats::DatasetStats;
use bmf_pp::metrics::throughput::Throughput;

fn main() {
    bmf_pp::util::logging::init();
    println!("TABLE 1 — dataset statistics and sampler throughput");
    common::hr();
    println!(
        "{:<11} {:>8} {:>8} {:>9} {:>10} | {:>12} {:>14} | paper(k-rows/s, M-ratings/s)",
        "dataset", "rows", "cols", "ratings", "spars.", "rows/s(k)", "ratings/s(M)"
    );
    common::hr();

    // paper Table 1 bottom rows
    let paper: &[(&str, f64, f64)] = &[
        ("movielens", 416.0, 70.0),
        ("netflix", 15.0, 5.5),
        ("yahoo", 27.0, 5.2),
        ("amazon", 911.0, 3.8),
    ];

    let mut results = Vec::new();
    for &(name, p_rows, p_ratings) in paper {
        let (profile, train, _test) = common::bench_dataset(name);
        let st = DatasetStats::compute(&train);
        let (gi, gj) = common::bench_grid(name);
        let cfg = TrainConfig::new(profile.k)
            .with_grid(gi, gj)
            .with_sweeps(4, 8)
            .with_tau(auto_tau(&train))
            .with_seed(2);
        // warm measurement: first run pays PJRT compilation; report the
        // steady-state second run through the same engine
        let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
        engine.train(&cfg, &train).expect("warmup");
        let res = engine.train(&cfg, &train).expect("train");
        let sweeps_per_block = res.stats.sweeps / res.stats.blocks.max(1);
        let tp = Throughput::measure(
            train.rows,
            train.cols,
            train.nnz(),
            sweeps_per_block,
            res.timings.total,
        );
        println!(
            "{:<11} {:>8} {:>8} {:>9} {:>10.0} | {:>12.1} {:>14.3} | ({p_rows}, {p_ratings})",
            name,
            st.rows,
            st.cols,
            st.ratings,
            st.sparsity,
            tp.rows_per_sec / 1e3,
            tp.ratings_per_sec / 1e6,
        );
        results.push((format!("{name}_rows_per_sec"), tp.rows_per_sec));
        results.push((format!("{name}_ratings_per_sec"), tp.ratings_per_sec));
    }
    common::hr();
    println!("expected shape: amazon & movielens lead rows/s (small K), movielens leads");
    println!("ratings/s (dense rows, small K); netflix/yahoo pay the K=100→{{16}} row cost.");

    // ---- within-block sweep pipelining: lockstep vs GASPI-style ----
    // 4 shard workers on one block; the pipelined run must show real
    // compute/communication overlap (V-side compute while the U side is
    // still sampling/publishing), which lockstep cannot have by definition
    println!();
    println!("WITHIN-BLOCK SWEEPS — lockstep vs pipelined (movielens, 4 shard workers)");
    common::hr();
    let (profile, train, _test) = common::bench_dataset("movielens");
    // pinned to the native backend: pipelined sweeps are native-only (on
    // HLO they fall back to lockstep, which would void the overlap assert)
    let base = TrainConfig::new(profile.k)
        .with_backend(bmf_pp::coordinator::BackendSpec::Native)
        .with_sweeps(4, 8)
        .with_workers(4)
        .with_tau(auto_tau(&train))
        .with_seed(3);
    let engine = Engine::new(&base.backend, base.block_parallelism);
    let mut sweep_rows = Vec::new();
    for (label, mode, tau_chunks) in [
        ("lockstep", SweepMode::Lockstep, 0usize),
        ("pipelined", SweepMode::Pipelined, 2),
    ] {
        let cfg = base
            .clone()
            .with_sweep_mode(mode)
            .with_chunk_rows(16)
            .with_staleness(tau_chunks);
        engine.train(&cfg, &train).expect("warmup");
        let res = engine.train(&cfg, &train).expect("train");
        println!(
            "{label:<10} wall={:<8.3}s compute={:<8.3}s sweep-overlap={:.4}s (tau={tau_chunks})",
            res.timings.total, res.stats.compute_secs, res.stats.comm_overlap_secs
        );
        sweep_rows.push((format!("{label}_wall_secs"), res.timings.total));
        sweep_rows.push((format!("{label}_overlap_secs"), res.stats.comm_overlap_secs));
        if mode == SweepMode::Lockstep {
            assert_eq!(
                res.stats.comm_overlap_secs, 0.0,
                "lockstep sweeps cannot overlap compute with the exchange"
            );
        } else {
            assert!(
                res.stats.comm_overlap_secs > 0.0,
                "pipelined sweeps must measure compute/communication overlap"
            );
        }
    }
    results.extend(sweep_rows);
    common::save_json("table1.json", &results);
}
