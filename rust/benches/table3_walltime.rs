//! Table 3: single-node wall-clock of BMF+PP, plain BMF, NOMAD and FPSGD
//! at a matched quality target. Paper values (hh:mm on 16 cores) printed
//! alongside; the reproduction target is the *structure*: BMF ≫ slower
//! than SGD methods, PP gives a 2-4x cut over plain BMF, NOMAD fastest.
//!
//!     cargo bench --bench table3_walltime

mod common;

use bmf_pp::baselines::sgd_common::SgdConfig;
use bmf_pp::baselines::{fpsgd, nomad};
use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, SchedulerMode, TrainConfig};
use bmf_pp::gibbs::NativeGibbs;
use bmf_pp::util::timer::Stopwatch;

fn main() {
    bmf_pp::util::logging::init();
    println!("TABLE 3 — wall-clock seconds, single machine (paper hh:mm @16 cores)");
    common::hr();
    println!(
        "{:<11} {:>14} {:>14} {:>14} {:>14}",
        "dataset", "BMF+PP", "BMF", "NOMAD", "FPSGD"
    );
    common::hr();

    let paper: &[(&str, &str, &str, &str, &str)] = &[
        ("movielens", "0:07", "0:14", "0:08", "0:09"),
        ("netflix", "2:02", "4:39", "0:08", "1:04"),
        ("yahoo", "2:13", "12:22", "0:10", "2:41"),
        ("amazon", "4:15", "13:02", "0:40", "2:28"),
    ];

    // matched budgets: BMF runs the same total sweeps PP spends per block;
    // SGD methods run a fixed epoch budget (they converge much earlier).
    let (burnin, samples) = (8usize, 16usize);
    let mut results = Vec::new();
    for &(name, pp_p, bmf_p, nomad_p, fpsgd_p) in paper {
        let (profile, train, test) = common::bench_dataset(name);
        let k = profile.k;
        let tau = auto_tau(&train);
        let (gi, gj) = common::bench_grid(name);

        let cfg = TrainConfig::new(k)
            .with_grid(gi, gj)
            .with_sweeps(burnin, samples)
            .with_tau(tau)
            .with_seed(4)
            .with_backend(BackendSpec::Native); // same backend for PP & BMF
        // cold engine per dataset: the measured wall-clock matches what a
        // fresh single-run launch pays, like the BMF/SGD columns below
        let sw = Stopwatch::start();
        let pp = Engine::new(&cfg.backend, cfg.block_parallelism)
            .train(&cfg, &train)
            .expect("pp");
        let t_pp = sw.secs();
        let rmse_pp = pp.rmse(&test);

        let sw = Stopwatch::start();
        let mut bmf = NativeGibbs::new(&train, k, tau, 4);
        for _ in 0..burnin + samples {
            bmf.sweep();
        }
        let t_bmf = sw.secs();
        let rmse_bmf = bmf.rmse(&test);

        let sgd = SgdConfig::new(k).with_epochs(30).with_threads(4).with_seed(4);
        let sw = Stopwatch::start();
        let m_nomad = nomad::train(&train, &sgd);
        let t_nomad = sw.secs();
        let sw = Stopwatch::start();
        let m_fpsgd = fpsgd::train(&train, &sgd);
        let t_fpsgd = sw.secs();

        println!(
            "{:<11} {:>7.2}s ({pp_p}) {:>7.2}s ({bmf_p}) {:>7.2}s ({nomad_p}) {:>7.2}s ({fpsgd_p})",
            name, t_pp, t_bmf, t_nomad, t_fpsgd
        );
        println!(
            "{:<11} rmse: pp={:.3} bmf={:.3} nomad={:.3} fpsgd={:.3}",
            "", rmse_pp, rmse_bmf, m_nomad.rmse(&test), m_fpsgd.rmse(&test)
        );
        results.push((format!("{name}_bmfpp_secs"), t_pp));
        results.push((format!("{name}_bmf_secs"), t_bmf));
        results.push((format!("{name}_nomad_secs"), t_nomad));
        results.push((format!("{name}_fpsgd_secs"), t_fpsgd));
        results.push((format!("{name}_pp_speedup_over_bmf"), t_bmf / t_pp));
    }
    common::hr();
    println!("expected shape: Gibbs (BMF) slowest; PP cuts BMF wall-clock ~2-4x via");
    println!("phase parallelism; SGD methods (NOMAD/FPSGD) fastest at similar RMSE.");

    // ---- barrier vs dependency-driven scheduling on a skewed grid ----
    // one row-block carries ~8x the nnz: the barrier scheduler stalls all
    // of phase (c) behind that straggler, the DAG scheduler overlaps it
    println!();
    println!("BARRIER vs DAG scheduling, skewed (imbalanced-nnz) 3x3 grid, movielens");
    common::hr();
    let (train, _test) = common::skewed_dataset("movielens", 8);
    let tau = auto_tau(&train);
    let mk = |mode: SchedulerMode| {
        let mut cfg = TrainConfig::new(8)
            .with_grid(3, 3)
            .with_sweeps(burnin, samples)
            .with_tau(tau)
            .with_seed(4)
            .with_backend(BackendSpec::Native)
            .with_scheduler(mode);
        // fixed slot count: idle accounting must not vary with host cores
        cfg.block_parallelism = 4;
        cfg
    };
    // one warm engine with exactly 4 slots serves both schedules, so the
    // barrier-vs-DAG comparison is not polluted by pool spawn costs
    let engine = Engine::new(&BackendSpec::Native, 4);
    let sw = Stopwatch::start();
    let bar = engine.train(&mk(SchedulerMode::Barrier), &train).expect("barrier");
    let t_bar = sw.secs();
    let sw = Stopwatch::start();
    let dag = engine.train(&mk(SchedulerMode::Dag), &train).expect("dag");
    let t_dag = sw.secs();
    assert_eq!(bar.u_mean, dag.u_mean, "scheduling must not change the posterior");
    println!(
        "{:<8} wall {:>7.2}s   straggler-idle {:>7.2}s   phase-overlap {:>6.2}s",
        "barrier", t_bar, bar.stats.idle_secs, bar.stats.overlap_secs
    );
    println!(
        "{:<8} wall {:>7.2}s   straggler-idle {:>7.2}s   phase-overlap {:>6.2}s",
        "dag", t_dag, dag.stats.idle_secs, dag.stats.overlap_secs
    );
    println!("dag speedup over barrier: {:.2}x", t_bar / t_dag);
    results.push(("skewed_barrier_secs".to_string(), t_bar));
    results.push(("skewed_dag_secs".to_string(), t_dag));
    results.push(("skewed_barrier_idle_secs".to_string(), bar.stats.idle_secs));
    results.push(("skewed_dag_idle_secs".to_string(), dag.stats.idle_secs));
    results.push(("skewed_dag_overlap_secs".to_string(), dag.stats.overlap_secs));
    // save before the wall-clock check so a timing flake on a loaded host
    // cannot discard the measured tables above
    common::save_json("table3.json", &results);
    assert!(
        dag.stats.idle_secs < bar.stats.idle_secs,
        "dag idle {:.3}s must undercut barrier idle {:.3}s on a skewed grid",
        dag.stats.idle_secs,
        bar.stats.idle_secs
    );
}
