//! §Multi-session bench: what the multi-tenant engine buys and costs.
//!
//!   M1 — two concurrent sessions vs back-to-back on one warm engine:
//!        wall-clock overlap, with a bitwise assert that interleaving
//!        never changes either posterior.
//!   M2 — priority latency: a small High-priority job submitted after a
//!        wide Low-priority one must land first (the queue-jump the
//!        shared ready-queue exists for), measured as completion times.
//!   M3 — cancel + resume: time to abort with a v3 checkpoint and the
//!        compute saved by resuming vs retraining from scratch, with a
//!        bitwise assert on the resumed posterior.
//!
//!     cargo bench --bench multi_session

mod common;

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, Priority, TrainConfig, TrainOutcome};
use bmf_pp::util::timer::Stopwatch;

fn main() {
    bmf_pp::util::logging::init();
    let mut results = Vec::new();
    let (_, train, _) = common::bench_dataset("movielens");
    let tau = auto_tau(&train);
    let k = 8;
    let cfg = |grid: (usize, usize), samples: usize, seed: u64| {
        TrainConfig::new(k)
            .with_backend(BackendSpec::Native)
            .with_grid(grid.0, grid.1)
            .with_sweeps(6, samples)
            .with_tau(tau)
            .with_seed(seed)
    };

    println!("M1 — two 3x3 sessions: concurrent vs sequential on one warm engine");
    {
        let engine = Engine::new(&BackendSpec::Native, 4);
        // warm the pool
        engine.train(&cfg((2, 2), 4, 1), &train).unwrap();

        let sw = Stopwatch::start();
        let r1 = engine.train(&cfg((3, 3), 12, 2), &train).unwrap();
        let r2 = engine.train(&cfg((3, 3), 12, 3), &train).unwrap();
        let sequential = sw.secs();

        let sw = Stopwatch::start();
        let s1 = engine.submit(cfg((3, 3), 12, 2), &train).unwrap();
        let s2 = engine.submit(cfg((3, 3), 12, 3), &train).unwrap();
        let c1 = s1.wait().unwrap().into_result().unwrap();
        let c2 = s2.wait().unwrap().into_result().unwrap();
        let concurrent = sw.secs();

        // interleaving two jobs on one queue must not move a single bit
        assert_eq!(c1.u_post.mean, r1.u_post.mean, "job 1 posterior changed");
        assert_eq!(c2.u_post.mean, r2.u_post.mean, "job 2 posterior changed");
        println!(
            "  sequential {sequential:.2}s vs concurrent {concurrent:.2}s ({:.2}x)",
            sequential / concurrent.max(1e-9)
        );
        results.push(("m1_sequential_secs".to_string(), sequential));
        results.push(("m1_concurrent_secs".to_string(), concurrent));
    }

    common::hr();
    println!("M2 — High-priority 2x2 job submitted after a wide Low-priority 4x4 job");
    {
        let engine = Engine::new(&BackendSpec::Native, 2);
        engine.train(&cfg((2, 2), 4, 4), &train).unwrap(); // warm

        let sw = Stopwatch::start();
        let low = engine
            .submit(cfg((4, 4), 16, 5).with_priority(Priority::Low), &train)
            .unwrap();
        let high = engine
            .submit(cfg((2, 2), 6, 6).with_priority(Priority::High), &train)
            .unwrap();
        high.wait().unwrap().into_result().unwrap();
        let t_high = sw.secs();
        let low_done_when_high_landed = low.status().is_terminal();
        low.wait().unwrap().into_result().unwrap();
        let t_low = sw.secs();

        // the acceptance property: the late High job finishes first
        assert!(
            !low_done_when_high_landed && t_high < t_low,
            "high-priority job did not overtake: high {t_high:.2}s vs low {t_low:.2}s"
        );
        println!("  high landed at {t_high:.2}s, wide low job at {t_low:.2}s");
        results.push(("m2_high_secs".to_string(), t_high));
        results.push(("m2_low_secs".to_string(), t_low));
    }

    common::hr();
    println!("M3 — cancel with v3 checkpoint, then resume vs retrain");
    {
        let engine = Engine::new(&BackendSpec::Native, 2);
        let ckpt = std::env::temp_dir()
            .join(format!("bmfpp_bench_abort_{}.json", std::process::id()));
        let base = cfg((3, 3), 16, 7);
        engine.train(&cfg((2, 2), 4, 7), &train).unwrap(); // warm

        let session = engine
            .submit(base.clone().with_checkpoint_on_cancel(ckpt.clone()), &train)
            .unwrap();
        while session.progress().0 < 3 && !session.status().is_terminal() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        session.cancel();
        match session.wait().unwrap() {
            TrainOutcome::Cancelled(info) => {
                println!(
                    "  cancelled after {} blocks, checkpoint: {}",
                    info.blocks_completed,
                    info.checkpoint.is_some()
                );
                let sw = Stopwatch::start();
                let resumed =
                    engine.train(&base.clone().with_resume_from(ckpt.clone()), &train).unwrap();
                let t_resume = sw.secs();
                let sw = Stopwatch::start();
                let full = engine.train(&base, &train).unwrap();
                let t_full = sw.secs();
                assert_eq!(
                    resumed.u_post.mean, full.u_post.mean,
                    "resume diverged from the uninterrupted run"
                );
                assert_eq!(resumed.stats.blocks_restored, info.blocks_completed);
                println!(
                    "  resume {t_resume:.2}s vs retrain {t_full:.2}s ({} blocks restored)",
                    resumed.stats.blocks_restored
                );
                results.push(("m3_resume_secs".to_string(), t_resume));
                results.push(("m3_retrain_secs".to_string(), t_full));
            }
            TrainOutcome::Completed(_) => {
                println!("  run finished before the cancel landed; skipping resume timing");
            }
            TrainOutcome::Failed(info) => panic!("unexpected failure: {}", info.error),
        }
        std::fs::remove_file(ckpt).ok();
    }

    common::save_json("multi_session.json", &results);
}
