//! §Perf probe: steady-state hot-path measurements feeding EXPERIMENTS.md.
//!
//!   P1 — sample_side latency, HLO (AOT Pallas kernel via PJRT) vs native
//!        rust oracle, identical inputs, warm engine; plus ratings/sec.
//!   P2 — L1 flavor A/B: Pallas-tiled vs pure-jnp-ref artifact (requires
//!        `python -m compile.aot --out-dir artifacts-ref --flavor ref`).
//!   P3 — padding overhead: real vs padded cells over a netflix-profile
//!        PP run (the cost of shape-specialized AOT artifacts).
//!   P4 — end-to-end trainer wall-clock, cold engines vs warm pool.
//!   P5 — snapshot metrics for the perf trajectory: sampler throughput
//!        (ratings/s), pipelined comm/compute overlap seconds, and
//!        per-job queue-wait seconds on a warm engine.
//!   P6 — serve: p50/p99 request latency and QPS of the HTTP predict
//!        path (request batcher + lock-free snapshot reads) under
//!        concurrent clients.
//!   P7 — out-of-core store: ingest throughput (ratings/s to shard files)
//!        and the shard-cache hit rate of a store-backed run whose byte
//!        budget holds roughly half the store.
//!   P9 — incremental update vs full retrain: wall-clock of
//!        `Engine::update` at ~1% and ~10% dirty ratings (deltas packed
//!        into whole blocks of a 4x4 grid) against a full retrain of the
//!        same config, plus the fraction of blocks actually re-sampled.
//!   P10 — kernel_bench: the optimized row-sampling kernel (`RowSampler`,
//!        scratch arena + packed-triangle accumulation + packed Cholesky)
//!        vs the retained naive reference (`sample_rows_reference`) on a
//!        256x256 block at density 0.12, k in {8, 16, 32} — rows/s and
//!        nnz/s per k, with the k=16 numbers as the gated headline
//!        metrics and the speedup ratios as informational extras.
//!
//!     cargo bench --bench perf_probe
//!
//! With `--json` (the CI bench-snapshot job) the run additionally writes
//! `bench_results/BENCH_PR10.json` — a flat machine-readable snapshot
//! (throughput, comm_overlap_secs, queue_wait_secs, shard_cache_hit_rate,
//! plus every probe result) that future PRs diff against the previous
//! snapshot via `scripts/bench_gate.sh`.

mod common;

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::Engine as TrainEngine;
use bmf_pp::coordinator::{BackendSpec, SweepMode, TrainConfig};
use bmf_pp::data::sparse::{Coo, Csr};
use bmf_pp::gibbs::native::{sample_rows_reference, sample_side_native, GibbsPrecision, RowSampler};
use bmf_pp::posterior::RowGaussians;
use bmf_pp::rng::{normal::standard_normal_vec, Rng};
#[cfg(feature = "pjrt")]
use bmf_pp::runtime::Engine;
use bmf_pp::serve::{ModelSource, ServeConfig, Server};
use bmf_pp::store::{ingest, ShardStore};
use bmf_pp::util::timer::Stopwatch;
use std::io::{Read, Write};
use std::sync::Arc;

fn random_block(n: usize, d: usize, density: f64, seed: u64) -> Coo {
    let mut rng = Rng::seed_from_u64(seed);
    let mut coo = Coo::new(n, d);
    for r in 0..n {
        for c in 0..d {
            if rng.bernoulli(density) {
                coo.push(r, c, (rng.uniform() * 4.0 + 1.0) as f32);
            }
        }
    }
    coo
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

#[cfg(not(feature = "pjrt"))]
fn probe_engine(_dir: &std::path::Path, label: &str, _results: &mut Vec<(String, f64)>) {
    println!("  {label}: skipped (built without the `pjrt` feature)");
}

#[cfg(feature = "pjrt")]
fn probe_engine(dir: &std::path::Path, label: &str, results: &mut Vec<(String, f64)>) {
    let engine = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            println!("  {label}: skipped ({e})");
            return;
        }
    };
    let (n, d, k) = (256usize, 256usize, 16usize);
    let block = random_block(n, d, 0.12, 9);
    let mut rng = Rng::seed_from_u64(10);
    let v = standard_normal_vec(&mut rng, d * k);
    let prior = RowGaussians::standard(n, k, 2.0);
    let noise = standard_normal_vec(&mut rng, n * k);
    // warm (compile)
    engine.sample_side(&block, false, &v, &prior, 2.0, &noise).unwrap();
    let mut times = Vec::new();
    for _ in 0..30 {
        let sw = Stopwatch::start();
        engine.sample_side(&block, false, &v, &prior, 2.0, &noise).unwrap();
        times.push(sw.secs());
    }
    let med = median(&mut times);
    let st = engine.stats();
    println!(
        "  {label}: median {:.2}ms / call  ({:.2}M masked-cells/s, compile {:.2}s)",
        med * 1e3,
        (n * d) as f64 / med / 1e6,
        st.compile_secs
    );
    results.push((format!("p1_{label}_ms"), med * 1e3));
}

#[cfg(not(feature = "pjrt"))]
fn probe_padding(_root: &std::path::Path, _results: &mut Vec<(String, f64)>) {
    println!("  skipped (built without the `pjrt` feature)");
}

#[cfg(feature = "pjrt")]
fn probe_padding(root: &std::path::Path, results: &mut Vec<(String, f64)>) {
    let (_, train, _) = common::bench_dataset("netflix");
    let dir = root.join("artifacts");
    if dir.join("manifest.json").exists() {
        let engine = Engine::new(&dir).unwrap();
        // run one side of each block shape through the engine once
        let grid = bmf_pp::partition::Grid::new(train.rows, train.cols, 4, 2);
        let blocks = grid.split(&train);
        let k = 16;
        for row in &blocks {
            for b in row {
                let mut rng = Rng::seed_from_u64(5);
                let v = standard_normal_vec(&mut rng, b.cols * k);
                let prior = RowGaussians::standard(b.rows, k, 1.0);
                let noise = standard_normal_vec(&mut rng, b.rows * k);
                engine.sample_side(b, false, &v, &prior, 1.0, &noise).unwrap();
            }
        }
        let st = engine.stats();
        let ratio = st.padded_cells as f64 / st.real_cells.max(1) as f64;
        println!("  padded/real cells = {:.2}x over {} executions", ratio, st.executions);
        results.push(("p3_padding_ratio".to_string(), ratio));
    } else {
        println!("  skipped: no artifacts");
    }
}

fn main() {
    bmf_pp::util::logging::init();
    let mut results = Vec::new();
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));

    println!("P1 — sample_side 256x256x16, steady state");
    probe_engine(&root.join("artifacts"), "hlo_pallas", &mut results);
    {
        let (n, d, k) = (256usize, 256usize, 16usize);
        let block = random_block(n, d, 0.12, 9);
        let csr = Csr::from_coo(&block);
        let mut rng = Rng::seed_from_u64(10);
        let v = standard_normal_vec(&mut rng, d * k);
        let prior = RowGaussians::standard(n, k, 2.0);
        let noise = standard_normal_vec(&mut rng, n * k);
        let mut times = Vec::new();
        for _ in 0..30 {
            let sw = Stopwatch::start();
            sample_side_native(&csr, &v, k, &prior, 2.0, &noise).unwrap();
            times.push(sw.secs());
        }
        let med = median(&mut times);
        println!(
            "  native: median {:.2}ms / call ({} nnz sparse path)",
            med * 1e3,
            block.nnz()
        );
        results.push(("p1_native_ms".to_string(), med * 1e3));
    }

    println!("\nP2 — L1 flavor A/B (pallas-tiled vs pure-jnp ref lowering)");
    if root.join("artifacts-ref/manifest.json").exists() {
        probe_engine(&root.join("artifacts-ref"), "hlo_ref", &mut results);
    } else {
        println!("  skipped: run `python -m compile.aot --out-dir artifacts-ref --flavor ref`");
    }

    println!("\nP3 — padding overhead on a netflix-profile PP run (grid 4x2)");
    probe_padding(root, &mut results);

    println!("\nP4 — trainer cold vs warm pool (movielens profile, 2x2)");
    {
        let (_, train, _) = common::bench_dataset("movielens");
        let cfg = TrainConfig::new(8)
            .with_grid(2, 2)
            .with_sweeps(6, 12)
            .with_tau(auto_tau(&train))
            .with_seed(6);
        let sw = Stopwatch::start();
        // cold: fresh single-run engine, compiles inside
        TrainEngine::new(&cfg.backend, cfg.block_parallelism).train(&cfg, &train).unwrap();
        let cold = sw.secs();
        let engine = TrainEngine::new(&cfg.backend, cfg.block_parallelism);
        engine.train(&cfg, &train).unwrap(); // warm the engine's pool
        let sw = Stopwatch::start();
        engine.train(&cfg, &train).unwrap();
        let warm = sw.secs();
        let backend = match cfg.backend.resolve() {
            BackendSpec::Hlo { .. } => "hlo",
            _ => "native",
        };
        println!("  [{backend}] cold {cold:.2}s vs warm {warm:.2}s ({:.1}x)", cold / warm);
        results.push(("p4_cold_secs".to_string(), cold));
        results.push(("p4_warm_secs".to_string(), warm));
    }

    println!("\nP5 — snapshot metrics (throughput / sweep overlap / queue wait)");
    {
        let (_, train, _) = common::bench_dataset("movielens");
        let tau = auto_tau(&train);
        let cfg = TrainConfig::new(16)
            .with_grid(2, 2)
            .with_sweeps(6, 12)
            .with_workers(2)
            .with_tau(tau)
            .with_seed(8);
        let engine = TrainEngine::new(&cfg.backend, cfg.block_parallelism);
        engine.train(&cfg, &train).unwrap(); // warm the pool

        // throughput + queue wait, measured through the session path the
        // multi-tenant engine actually serves
        let result = engine
            .submit(cfg.clone(), &train)
            .unwrap()
            .wait()
            .unwrap()
            .into_result()
            .unwrap();
        let ratings_per_sec =
            result.stats.ratings_processed as f64 / result.timings.total.max(1e-9);
        println!(
            "  throughput {:.2}M ratings/s, queue wait {:.4}s",
            ratings_per_sec / 1e6,
            result.stats.queue_wait_secs
        );
        results.push(("throughput_ratings_per_sec".to_string(), ratings_per_sec));
        results.push(("queue_wait_secs".to_string(), result.stats.queue_wait_secs));

        // comm/compute overlap from a pipelined run on the same engine
        let pipe = engine
            .train(
                &cfg.with_sweep_mode(SweepMode::Pipelined).with_chunk_rows(64).with_staleness(1),
                &train,
            )
            .unwrap();
        println!("  pipelined comm overlap {:.4}s", pipe.stats.comm_overlap_secs);
        results.push(("comm_overlap_secs".to_string(), pipe.stats.comm_overlap_secs));
    }

    println!("\nP6 — serve: HTTP predict latency / QPS (4 clients x 300 requests)");
    {
        let (_, train, _) = common::bench_dataset("movielens");
        let cfg = TrainConfig::new(8).with_grid(2, 2).with_sweeps(4, 8).with_seed(9);
        let model = TrainEngine::new(&cfg.backend, cfg.block_parallelism)
            .train(&cfg, &train)
            .unwrap()
            .model;
        let (rows, cols) = (model.rows(), model.cols());
        let dir =
            std::env::temp_dir().join(format!("bmfpp_perf_serve_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        bmf_pp::coordinator::checkpoint::save(&model, &path).unwrap();

        let server = Server::start(
            ServeConfig::default().with_addr("127.0.0.1:0").with_threads(4),
            ModelSource::File(path),
        )
        .expect("serve probe server");
        let addr = server.addr();
        let predict = move |row: usize, col: usize| {
            let mut stream = std::net::TcpStream::connect(addr).expect("connect");
            let req = format!(
                "GET /predict?row={row}&col={col} HTTP/1.1\r\nhost: probe\r\n\
                 connection: close\r\n\r\n"
            );
            stream.write_all(req.as_bytes()).expect("send");
            let mut raw = String::new();
            stream.read_to_string(&mut raw).expect("recv");
            assert!(raw.starts_with("HTTP/1.1 200"), "probe request failed: {raw}");
        };
        predict(0, 0); // warm the accept loop and worker pool
        let (clients, per_client) = (4usize, 300usize);
        let sw = Stopwatch::start();
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                std::thread::spawn(move || {
                    for i in 0..per_client {
                        predict((c * per_client + i) % rows, i % cols);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("serve probe client panicked");
        }
        let wall = sw.secs();
        let stats = server.stop();
        let qps = (clients * per_client) as f64 / wall.max(1e-9);
        println!(
            "  p50 {:.3}ms  p99 {:.3}ms  {qps:.0} qps  ({} batches, max batch {})",
            stats.p50_ms, stats.p99_ms, stats.batches, stats.max_batch
        );
        results.push(("serve_p50_ms".to_string(), stats.p50_ms));
        results.push(("serve_p99_ms".to_string(), stats.p99_ms));
        results.push(("serve_qps".to_string(), qps));
        std::fs::remove_dir_all(&dir).ok();
    }

    println!("\nP7 — out-of-core store: ingest throughput + shard-cache hit rate");
    {
        let (_, train, _) = common::bench_dataset("movielens");
        let dir =
            std::env::temp_dir().join(format!("bmfpp_perf_store_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let sw = Stopwatch::start();
        let report = ingest(&train, 2, 2, &dir).unwrap();
        let ingest_secs = sw.secs();
        let ingest_rps = report.nnz as f64 / ingest_secs.max(1e-9);
        println!(
            "  ingest: {} ratings -> {} shards ({} bytes) in {ingest_secs:.3}s \
             ({:.2}M ratings/s)",
            report.nnz,
            report.blocks,
            report.bytes,
            ingest_rps / 1e6
        );
        results.push(("p7_ingest_ratings_per_sec".to_string(), ingest_rps));

        // budget ~half the store: real cache churn without degenerate thrash
        let store = Arc::new(ShardStore::open(&dir).unwrap());
        let cfg = TrainConfig::new(8)
            .with_grid(2, 2)
            .with_sweeps(4, 8)
            .with_tau(auto_tau(&train))
            .with_seed(11)
            .with_cache_bytes(report.bytes / 2);
        let engine = TrainEngine::new(&cfg.backend, cfg.block_parallelism);
        let result = engine.train_store(&cfg, store).unwrap();
        let (hits, misses) = (result.stats.shard_hits, result.stats.shard_misses);
        // prefetch_hits is a subset of hits, so the rate is hits over all gets
        let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
        println!(
            "  cache: {hits} hits / {misses} misses ({} prefetch, {} evictions) \
             -> hit rate {hit_rate:.2}",
            result.stats.shard_prefetch_hits, result.stats.shard_evictions
        );
        results.push(("shard_cache_hit_rate".to_string(), hit_rate));
        results.push(("shard_hits".to_string(), hits as f64));
        results.push(("shard_misses".to_string(), misses as f64));
        results.push(("prefetch_hits".to_string(), result.stats.shard_prefetch_hits as f64));
        std::fs::remove_dir_all(&dir).ok();
    }

    println!("\nP9 — incremental update vs full retrain (movielens profile, 4x4)");
    {
        let (_, train, _) = common::bench_dataset("movielens");
        let cfg = TrainConfig::new(8)
            .with_grid(4, 4)
            .with_sweeps(4, 8)
            .with_tau(auto_tau(&train))
            .with_seed(13);
        let ckpt_dir =
            std::env::temp_dir().join(format!("bmfpp_perf_update_{}", std::process::id()));
        std::fs::remove_dir_all(&ckpt_dir).ok();
        let engine = TrainEngine::new(&cfg.backend, cfg.block_parallelism);
        // the prior generation every update seeds from (also warms the pool)
        engine
            .train(&cfg.clone().with_checkpoint_every(1).with_checkpoint_dir(&ckpt_dir), &train)
            .unwrap();
        let prior = bmf_pp::online::load_prior(&ckpt_dir).unwrap();

        let sw = Stopwatch::start();
        engine.train(&cfg, &train).unwrap();
        let full_secs = sw.secs();
        println!("  full retrain: {full_secs:.3}s ({} blocks)", 4 * 4);
        results.push(("p9_full_retrain_secs".to_string(), full_secs));

        let total_blocks = (prior.grid.0 * prior.grid.1) as f64;
        for (label, frac) in [("1pct", 0.01), ("10pct", 0.10)] {
            let delta = dirty_delta(&train, prior.grid, frac);
            let sw = Stopwatch::start();
            let result = engine
                .update(cfg.clone(), &prior, &delta, &train)
                .unwrap()
                .wait()
                .unwrap()
                .into_result()
                .unwrap();
            let secs = sw.secs();
            let ratio = result.stats.blocks as f64 / total_blocks;
            println!(
                "  update {label} dirty ({} ratings): {secs:.3}s, {}/{} blocks \
                 re-sampled ({:.1}x vs retrain)",
                delta.len(),
                result.stats.blocks,
                total_blocks as usize,
                full_secs / secs.max(1e-9)
            );
            results.push((format!("p9_update_{label}_secs"), secs));
            if frac == 0.10 {
                results.push(("p9_blocks_resampled_ratio".to_string(), ratio));
            }
        }
        std::fs::remove_dir_all(&ckpt_dir).ok();
    }

    println!("\nP10 — kernel_bench: optimized RowSampler vs naive reference (256x256, 12%)");
    {
        let (n, d) = (256usize, 256usize);
        for k in [8usize, 16, 32] {
            let block = random_block(n, d, 0.12, 9);
            let csr = Csr::from_coo(&block);
            let nnz = block.nnz();
            let mut rng = Rng::seed_from_u64(10);
            let v = standard_normal_vec(&mut rng, d * k);
            let prior = RowGaussians::standard(n, k, 2.0);
            let noise = standard_normal_vec(&mut rng, n * k);
            let mut samples = vec![0.0f32; n * k];
            let mut means = vec![0.0f32; n * k];

            // optimized: one arena reused across reps, like a real sweep
            let mut sampler = RowSampler::new(k, GibbsPrecision::F64);
            sampler
                .sample_rows_into(&csr, 0..n, &v, &prior, 2.0, &noise, &mut samples, &mut means)
                .unwrap(); // warm caches + page in buffers
            let mut opt_times = Vec::new();
            for _ in 0..30 {
                let sw = Stopwatch::start();
                sampler
                    .sample_rows_into(
                        &csr, 0..n, &v, &prior, 2.0, &noise, &mut samples, &mut means,
                    )
                    .unwrap();
                opt_times.push(sw.secs());
            }
            let opt = median(&mut opt_times);

            let mut ref_times = Vec::new();
            for _ in 0..30 {
                let sw = Stopwatch::start();
                sample_rows_reference(
                    &csr, 0..n, &v, k, &prior, 2.0, &noise, &mut samples, &mut means,
                )
                .unwrap();
                ref_times.push(sw.secs());
            }
            let naive = median(&mut ref_times);

            let rows_per_sec = n as f64 / opt;
            let nnz_per_sec = nnz as f64 / opt;
            let speedup = naive / opt.max(1e-12);
            println!(
                "  k={k:<2} optimized {:.3}ms ({:.2}M rows/s, {:.2}M nnz/s)  \
                 reference {:.3}ms  speedup {speedup:.2}x",
                opt * 1e3,
                rows_per_sec / 1e6,
                nnz_per_sec / 1e6,
                naive * 1e3,
            );
            results.push((format!("p10_kernel_rows_per_sec_k{k}"), rows_per_sec));
            results.push((format!("p10_kernel_nnz_per_sec_k{k}"), nnz_per_sec));
            results.push((format!("p10_kernel_speedup_k{k}"), speedup));
            if k == 16 {
                // the gated headline metrics (see scripts/bench_gate.sh)
                results.push(("p10_kernel_rows_per_sec".to_string(), rows_per_sec));
                results.push(("p10_kernel_nnz_per_sec".to_string(), nnz_per_sec));
            }
        }
    }

    common::save_json("perf_probe.json", &results);
    // machine-readable snapshot for the CI bench-snapshot artifact
    if std::env::args().any(|a| a == "--json") {
        common::save_json("BENCH_PR10.json", &results);
        println!("\nsnapshot written to bench_results/BENCH_PR10.json");
    }
}

/// A delta re-rating ~`frac` of the train ratings (+0.25), packed into
/// whole blocks walked row-major — the dirty set stays proportional to
/// the delta instead of spraying across the grid.
fn dirty_delta(train: &Coo, grid: (usize, usize), frac: f64) -> bmf_pp::online::RatingDelta {
    let g = bmf_pp::partition::Grid::new(train.rows, train.cols, grid.0, grid.1);
    let target = ((train.nnz() as f64) * frac).ceil() as usize;
    let mut delta = bmf_pp::online::RatingDelta::new(train.rows, train.cols);
    'blocks: for bi in 0..grid.0 {
        for bj in 0..grid.1 {
            let (r0, r1) = g.row_range(bi);
            let (c0, c1) = g.col_range(bj);
            for e in &train.entries {
                let (r, c) = (e.row as usize, e.col as usize);
                if r >= r0 && r < r1 && c >= c0 && c < c1 {
                    delta.push(r, c, e.val + 0.25);
                }
            }
            if delta.len() >= target {
                break 'blocks;
            }
        }
    }
    delta
}
