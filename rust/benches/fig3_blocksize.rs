//! Figure 3: block-size exploration on the Netflix profile — RMSE vs
//! wall-clock vs block aspect ratio for a sweep of I×J grids (the paper's
//! bubble plot; here a table + JSON series). Paper finding to reproduce:
//! near-square blocks Pareto-dominate; with Netflix's 27:1 row/col ratio
//! the winner is strongly row-heavy (paper: 20x3).
//!
//!     cargo bench --bench fig3_blocksize

mod common;

use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig};
use bmf_pp::partition::balance;

fn main() {
    bmf_pp::util::logging::init();
    let (profile, train, test) = common::bench_dataset("netflix");
    let tau = auto_tau(&train);
    println!(
        "FIGURE 3 — block-size exploration, netflix profile {}x{} ({} ratings)",
        train.rows,
        train.cols,
        train.nnz()
    );
    common::hr();
    println!(
        "{:<8} {:>9} {:>10} {:>10} {:>9} {:>12}",
        "grid", "aspect", "rmse", "wall(s)", "blocks", "node-secs"
    );
    common::hr();

    let grids: &[(usize, usize)] = &[
        (1, 1),
        (2, 2),
        (4, 4),
        (8, 8),
        (2, 1),
        (4, 1),
        (8, 2),
        (12, 2),
        (16, 2),
        (20, 3),
        (16, 8),
        (3, 20), // wrong-way rectangular: should lose
    ];

    let mut results = Vec::new();
    let mut pareto: Vec<(f64, f64, String)> = Vec::new();
    // the whole sweep runs on one warm engine: every grid shares the pool
    let engine = Engine::new(&BackendSpec::Native, TrainConfig::new(1).block_parallelism);
    for &(i, j) in grids {
        if i > train.rows || j > train.cols {
            continue;
        }
        let cfg = TrainConfig::new(profile.k)
            .with_grid(i, j)
            .with_sweeps(8, 16)
            .with_tau(tau)
            .with_seed(5)
            .with_backend(BackendSpec::Native);
        let res = match engine.train(&cfg, &train) {
            Ok(r) => r,
            Err(e) => {
                println!("{:<8} skipped: {e}", format!("{i}x{j}"));
                continue;
            }
        };
        let rmse = res.rmse(&test);
        let aspect = balance::block_aspect(train.rows, train.cols, i, j);
        println!(
            "{:<8} {:>9.2} {:>10.4} {:>10.2} {:>9} {:>12.2}",
            format!("{i}x{j}"),
            aspect,
            rmse,
            res.timings.total,
            res.stats.blocks,
            res.stats.compute_secs
        );
        results.push((format!("{i}x{j}_rmse"), rmse));
        results.push((format!("{i}x{j}_secs"), res.timings.total));
        results.push((format!("{i}x{j}_aspect"), aspect));
        pareto.push((res.timings.total, rmse, format!("{i}x{j}")));
    }
    common::hr();

    // Pareto set in (time, rmse)
    pareto.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut best_rmse = f64::INFINITY;
    let front: Vec<String> = pareto
        .iter()
        .filter(|(_, r, _)| {
            if *r < best_rmse {
                best_rmse = *r;
                true
            } else {
                false
            }
        })
        .map(|(_, _, g)| g.clone())
        .collect();
    println!("pareto (time→rmse): {}", front.join(" → "));
    println!("expected: row-heavy grids (e.g. 8x2..20x3) on the front; 3x20 dominated.");
    common::save_json("fig3.json", &results);
}
