//! Shared bench harness (criterion is unavailable offline): dataset
//! setup at bench scales, table formatting, and JSON result dumps.

// each bench binary compiles its own copy and uses a subset of the helpers
#![allow(dead_code)]

use bmf_pp::data::generator::{DatasetProfile, SyntheticDataset};
use bmf_pp::data::split::holdout_split_covered;
use bmf_pp::data::sparse::Coo;

/// Bench-scale factor per profile: keeps every dataset seconds-sized while
/// preserving the Table-1 shape statistics.
pub fn bench_scale(name: &str) -> f64 {
    match name {
        "movielens" => 0.002,
        "netflix" => 0.002,
        "yahoo" => 0.0004,
        "amazon" => 0.00002,
        _ => 0.002,
    }
}

/// (profile, train, test) at bench scale.
pub fn bench_dataset(name: &str) -> (DatasetProfile, Coo, Coo) {
    let profile = DatasetProfile::by_name(name).expect("profile");
    let ds = SyntheticDataset::generate(profile.clone(), bench_scale(name), 1234);
    let (train, test) = holdout_split_covered(&ds.ratings, 0.2, 1235);
    (profile, train, test)
}

/// Grid used for BMF+PP per dataset in the table benches (near-square
/// blocks per §3.3; row-heavy for Netflix).
pub fn bench_grid(name: &str) -> (usize, usize) {
    match name {
        "netflix" => (4, 2),
        "yahoo" => (2, 2),
        "amazon" => (2, 2),
        _ => (2, 2),
    }
}

/// A bench dataset with one heavily over-dense row stripe: the middle
/// row-block of a 3-row grid carries ~`factor`x the observations of its
/// siblings, making its phase-(b) block a straggler. Used to measure what
/// barrier-free scheduling buys on imbalanced grids.
pub fn skewed_dataset(name: &str, factor: usize) -> (Coo, Coo) {
    let (_, train, test) = bench_dataset(name);
    let mut skewed = train.clone();
    let r0 = train.rows / 3;
    let r1 = 2 * train.rows / 3;
    let stripe: Vec<(usize, usize, f32)> = train
        .entries
        .iter()
        .filter(|e| (e.row as usize) >= r0 && (e.row as usize) < r1)
        .map(|e| (e.row as usize, e.col as usize, e.val))
        .collect();
    for _ in 1..factor.max(1) {
        for &(r, c, v) in &stripe {
            skewed.push(r, c, v);
        }
    }
    (skewed, test)
}

pub fn hr() {
    println!("{}", "-".repeat(78));
}

/// Save a list of (key, value) pairs as a flat JSON object next to the
/// bench output (picked up for EXPERIMENTS.md).
pub fn save_json(file: &str, pairs: &[(String, f64)]) {
    use bmf_pp::util::json::Json;
    let obj = Json::Obj(
        pairs.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
    );
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir).ok();
    std::fs::write(dir.join(file), bmf_pp::util::json::to_string_pretty(&obj)).ok();
}
