//! Table 2: test RMSE of BMF+PP vs NOMAD vs FPSGD on the four dataset
//! profiles. Paper values printed alongside; the reproduction target is
//! the *ordering*: BMF+PP ≲ NOMAD/FPSGD (slightly better or equal).
//!
//!     cargo bench --bench table2_rmse

mod common;

use bmf_pp::baselines::als::AlsConfig;
use bmf_pp::baselines::cgd::CgdConfig;
use bmf_pp::baselines::sgd_common::SgdConfig;
use bmf_pp::baselines::sgld::SgldConfig;
use bmf_pp::baselines::{als, cgd, fpsgd, nomad, sgld};
use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{Engine, TrainConfig};

fn main() {
    bmf_pp::util::logging::init();
    println!("TABLE 2 — RMSE on held-out test sets (paper values in parentheses;");
    println!("          ALS/CGD/SGLD are this repo's extra related-work columns)");
    common::hr();
    println!(
        "{:<11} {:>15} {:>15} {:>15} {:>7} {:>7} {:>7}",
        "dataset", "BMF+PP", "NOMAD", "FPSGD", "ALS", "CGD", "SGLD"
    );
    common::hr();

    let paper: &[(&str, f64, f64, f64)] = &[
        ("movielens", 0.76, 0.77, 0.77),
        ("netflix", 0.90, 0.91, 0.92),
        ("yahoo", 21.79, 21.91, 21.78),
        ("amazon", 1.13, 1.20, 1.15),
    ];

    let mut results = Vec::new();
    // all four dataset rows train on one warm engine
    let base = TrainConfig::new(1);
    let engine = Engine::new(&base.backend, base.block_parallelism);
    for &(name, p_pp, p_nomad, p_fpsgd) in paper {
        let (profile, train, test) = common::bench_dataset(name);
        let k = profile.k;
        let (gi, gj) = common::bench_grid(name);

        let cfg = TrainConfig::new(k)
            .with_grid(gi, gj)
            .with_sweeps(10, 24)
            .with_tau(auto_tau(&train))
            .with_seed(3);
        let pp_rmse = engine.train(&cfg, &train).expect("pp").rmse(&test);

        let sgd = SgdConfig::new(k).with_epochs(30).with_threads(4).with_seed(3);
        let nomad_rmse = nomad::train(&train, &sgd).rmse(&test);
        let fpsgd_rmse = fpsgd::train(&train, &sgd).rmse(&test);
        let als_rmse = als::train(&train, &AlsConfig::new(k)).rmse(&test);
        let cgd_rmse = cgd::train(&train, &CgdConfig::new(k)).rmse(&test);
        let sgld_rmse = sgld::train(&train, &SgldConfig::new(k)).rmse(&test);

        println!(
            "{:<11} {:>7.3} ({p_pp:>5.2}) {:>7.3} ({p_nomad:>5.2}) {:>7.3} ({p_fpsgd:>5.2}) {:>7.3} {:>7.3} {:>7.3}",
            name, pp_rmse, nomad_rmse, fpsgd_rmse, als_rmse, cgd_rmse, sgld_rmse
        );
        results.push((format!("{name}_bmfpp"), pp_rmse));
        results.push((format!("{name}_nomad"), nomad_rmse));
        results.push((format!("{name}_fpsgd"), fpsgd_rmse));
        results.push((format!("{name}_als"), als_rmse));
        results.push((format!("{name}_cgd"), cgd_rmse));
        results.push((format!("{name}_sgld"), sgld_rmse));
    }
    common::hr();
    println!("expected shape: all three close; Bayesian BMF+PP equal-or-slightly-better,");
    println!("biggest Bayesian margin on the sparsest dataset (amazon).");
    common::save_json("table2.json", &results);
}
