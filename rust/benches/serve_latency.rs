//! §Serve bench: latency/throughput of the `bmf-pp serve` HTTP path
//! (request batcher + lock-free snapshot reads) at several client
//! concurrencies, each level against a fresh server so the latency
//! window is clean.
//!
//!     cargo bench --bench serve_latency
//!
//! Writes `bench_results/serve_latency.json` with per-level p50/p99/QPS.

mod common;

use bmf_pp::coordinator::{checkpoint, Engine, TrainConfig};
use bmf_pp::serve::{ModelSource, ServeConfig, Server};
use bmf_pp::util::timer::Stopwatch;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

const PER_CLIENT: usize = 400;

/// One `GET /predict` over a fresh connection; returns the HTTP status.
fn predict_once(addr: SocketAddr, row: usize, col: usize) -> u16 {
    let mut stream = TcpStream::connect(addr).expect("connect");
    let req =
        format!("GET /predict?row={row}&col={col} HTTP/1.1\r\nhost: bench\r\nconnection: close\r\n\r\n");
    stream.write_all(req.as_bytes()).expect("send");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("recv");
    raw.split_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// `clients` threads fire `PER_CLIENT` predicts each; returns wall secs.
fn hammer(addr: SocketAddr, clients: usize, rows: usize, cols: usize) -> f64 {
    let sw = Stopwatch::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                for i in 0..PER_CLIENT {
                    let status =
                        predict_once(addr, (c * PER_CLIENT + i) % rows, i % cols);
                    assert_eq!(status, 200, "bench request failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    sw.secs()
}

fn main() {
    bmf_pp::util::logging::init();
    let mut results = Vec::new();

    let (_, train, _) = common::bench_dataset("movielens");
    let cfg = TrainConfig::new(8).with_grid(2, 2).with_sweeps(4, 8).with_seed(11);
    let engine = Engine::new(&cfg.backend, cfg.block_parallelism);
    let model = engine.train(&cfg, &train).unwrap().model;
    let (rows, cols) = (model.rows(), model.cols());

    let dir = std::env::temp_dir().join(format!("bmfpp_serve_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    checkpoint::save(&model, &path).unwrap();

    println!("serve latency/QPS ({rows}x{cols} model, {PER_CLIENT} predicts per client)");
    println!("{:>8} {:>10} {:>10} {:>10}", "clients", "p50 ms", "p99 ms", "qps");
    for clients in [1usize, 2, 4, 8] {
        let server = Server::start(
            ServeConfig::default().with_addr("127.0.0.1:0").with_threads(4),
            ModelSource::File(path.clone()),
        )
        .expect("server start");
        let addr = server.addr();
        // warm the accept loop + worker pool before the timed window
        assert_eq!(predict_once(addr, 0, 0), 200);
        let wall = hammer(addr, clients, rows, cols);
        let stats = server.stop();
        let qps = (clients * PER_CLIENT) as f64 / wall.max(1e-9);
        println!("{clients:>8} {:>10.3} {:>10.3} {qps:>10.0}", stats.p50_ms, stats.p99_ms);
        results.push((format!("serve_c{clients}_p50_ms"), stats.p50_ms));
        results.push((format!("serve_c{clients}_p99_ms"), stats.p99_ms));
        results.push((format!("serve_c{clients}_qps"), qps));
    }

    common::save_json("serve_latency.json", &results);
    println!("results written to bench_results/serve_latency.json");
    std::fs::remove_dir_all(&dir).ok();
}
