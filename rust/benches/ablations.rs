//! Ablations of the design choices DESIGN.md calls out:
//!
//!   A1 — posterior propagation vs independent blocks (the identifiability
//!        problem PP exists to solve: naive embarrassingly-parallel MCMC
//!        averages posteriors from unaligned factor rotations).
//!   A2 — sweep reduction in phases (b)/(c) (paper §4 future work).
//!   A3 — within-block workers 1/2/4 (the distributed-BMF level).
//!
//!     cargo bench --bench ablations

mod common;

use bmf_pp::coordinator::backend::{BlockBackend, BlockData};
use bmf_pp::coordinator::block_task::{run_block, BlockTaskCfg};
use bmf_pp::coordinator::config::auto_tau;
use bmf_pp::coordinator::{BackendSpec, Engine, TrainConfig};
use bmf_pp::metrics::rmse::rmse_with;
use bmf_pp::partition::Grid;
use bmf_pp::util::timer::Stopwatch;

/// A1 baseline: run every block independently with fresh priors (no
/// propagation) and stitch factors by averaging each row's posterior means
/// across the blocks that touch it.
fn independent_blocks_rmse(
    train: &bmf_pp::data::sparse::Coo,
    test: &bmf_pp::data::sparse::Coo,
    k: usize,
    tau: f64,
    grid: (usize, usize),
) -> f64 {
    let g = Grid::new(train.rows, train.cols, grid.0, grid.1);
    let global_mean = train.mean();
    let mut centered = train.clone();
    for e in centered.entries.iter_mut() {
        e.val -= global_mean as f32;
    }
    let blocks = g.split(&centered);
    let backend = BlockBackend::Native;
    let mut u_sum = vec![0.0f64; train.rows * k];
    let mut u_cnt = vec![0.0f64; train.rows];
    let mut v_sum = vec![0.0f64; train.cols * k];
    let mut v_cnt = vec![0.0f64; train.cols];
    for i in 0..grid.0 {
        for j in 0..grid.1 {
            let data = BlockData::new(blocks[i][j].clone());
            let cfg = BlockTaskCfg {
                k,
                tau,
                burnin: 8,
                samples: 16,
                workers: 1,
                ridge: 1e-2,
                seed: 7 + (i * 31 + j) as u64,
                sweep: bmf_pp::coordinator::SweepMode::Lockstep,
                chunk_rows: 256,
                staleness: 0,
                precision: bmf_pp::gibbs::GibbsPrecision::F64,
            };
            let (post, _) =
                run_block(&backend, &data, &cfg, None, None, Default::default()).unwrap();
            let (r0, _) = g.row_range(i);
            let (c0, _) = g.col_range(j);
            for r in 0..post.u.n {
                for d in 0..k {
                    u_sum[(r0 + r) * k + d] += post.u.row_mean(r)[d];
                }
                u_cnt[r0 + r] += 1.0;
            }
            for c in 0..post.v.n {
                for d in 0..k {
                    v_sum[(c0 + c) * k + d] += post.v.row_mean(c)[d];
                }
                v_cnt[c0 + c] += 1.0;
            }
        }
    }
    rmse_with(test, |r, c| {
        let mut dot = global_mean;
        for d in 0..k {
            let u = u_sum[r * k + d] / u_cnt[r].max(1.0);
            let v = v_sum[c * k + d] / v_cnt[c].max(1.0);
            dot += u * v;
        }
        dot
    })
}

fn main() {
    bmf_pp::util::logging::init();
    let (profile, train, test) = common::bench_dataset("netflix");
    let k = profile.k;
    let tau = auto_tau(&train);
    let mut results = Vec::new();
    // every PP ablation below runs on this one warm engine
    let engine = Engine::new(&BackendSpec::Native, TrainConfig::new(1).block_parallelism);

    println!("ABLATION A1 — posterior propagation vs independent blocks (grid 4x2)");
    common::hr();
    let cfg = TrainConfig::new(k)
        .with_grid(4, 2)
        .with_sweeps(8, 16)
        .with_tau(tau)
        .with_seed(7)
        .with_backend(BackendSpec::Native);
    let pp_rmse = engine.train(&cfg, &train).unwrap().rmse(&test);
    let indep_rmse = independent_blocks_rmse(&train, &test, k, tau, (4, 2));
    println!("  with propagation   : rmse {pp_rmse:.4}");
    println!("  independent blocks : rmse {indep_rmse:.4}");
    println!("  expected: propagation clearly better (identifiability).");
    results.push(("a1_pp_rmse".to_string(), pp_rmse));
    results.push(("a1_indep_rmse".to_string(), indep_rmse));

    println!("\nABLATION A2 — sweep reduction in phases b/c (paper §4)");
    common::hr();
    for frac in [1.0f64, 0.5, 0.25] {
        let mut c = cfg.clone();
        c.phase_sample_frac = frac;
        let sw = Stopwatch::start();
        let res = engine.train(&c, &train).unwrap();
        let rmse = res.rmse(&test);
        println!(
            "  frac={frac:<4} rmse={rmse:.4} wall={:>6.2}s node-secs={:>7.2}",
            sw.secs(),
            res.stats.compute_secs
        );
        results.push((format!("a2_frac{frac}_rmse"), rmse));
        results.push((format!("a2_frac{frac}_secs"), res.stats.compute_secs));
    }
    println!("  expected: fewer phase-b/c samples cut compute with modest RMSE cost.");

    println!("\nABLATION A3 — within-block workers (distributed BMF level)");
    common::hr();
    // workers only pay off once the per-half-sweep compute dwarfs the
    // thread fork/gather cost — use a 5x larger netflix instance
    let big = bmf_pp::data::generator::SyntheticDataset::generate(
        bmf_pp::data::generator::DatasetProfile::netflix(),
        0.01,
        99,
    );
    let (big_train, big_test) =
        bmf_pp::data::split::holdout_split_covered(&big.ratings, 0.2, 98);
    let big_tau = auto_tau(&big_train);
    println!(
        "  block: {}x{}, {} ratings, K={k}",
        big_train.rows,
        big_train.cols,
        big_train.nnz()
    );
    let mut base_rmse = None;
    for workers in [1usize, 2, 4, 8] {
        let mut c = TrainConfig::new(k)
            .with_grid(1, 1)
            .with_sweeps(4, 8)
            .with_tau(big_tau)
            .with_seed(7)
            .with_workers(workers)
            .with_backend(BackendSpec::Native);
        c.block_parallelism = 1;
        let sw = Stopwatch::start();
        let res = engine.train(&c, &big_train).unwrap();
        let rmse = res.rmse(&big_test);
        println!("  workers={workers} wall={:>6.2}s rmse={rmse:.4}", sw.secs());
        results.push((format!("a3_w{workers}_secs"), sw.secs()));
        match base_rmse {
            None => base_rmse = Some(rmse),
            Some(b) => assert!((rmse - b).abs() < 1e-9, "sharding changed the math"),
        }
    }
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    println!(
        "  expected: identical RMSE (sharding is exact). wall-clock gains need >1 core \
         (this host: {cores}); multi-node projections come from cluster::sim."
    );

    println!("\nABLATION A4 — MPI allgather vs GASPI one-sided overlap (paper §4)");
    common::hr();
    {
        use bmf_pp::cluster::model::{BlockCost, ClusterModel, CommBackend};
        let mut mpi = ClusterModel::default();
        mpi.comm = CommBackend::Mpi;
        let mut gaspi = mpi;
        gaspi.comm = CommBackend::Gaspi;
        // two regimes: the whole matrix as one block (compute-bound) and a
        // 32x32-grid block (comm share grows — where one-sided overlap pays)
        let cases = [
            ("netflix 1x1 block", BlockCost { rows: 480_200, cols: 17_800, nnz: 100_000_000 }),
            (
                "netflix 32x32 block",
                BlockCost { rows: 480_200 / 32, cols: 17_800 / 32, nnz: 100_000_000 / 1024 },
            ),
        ];
        for (label, b) in cases {
            println!("  {label}:");
            println!("  {:<7} {:>12} {:>12} {:>8}", "nodes", "mpi(s)", "gaspi(s)", "gain");
            for w in [2usize, 8, 32, 128] {
                let t_m = mpi.block_secs(&b, 32, 28, w);
                let t_g = gaspi.block_secs(&b, 32, 28, w);
                println!(
                    "  {w:<7} {t_m:>12.3} {t_g:>12.3} {:>7.1}%",
                    (1.0 - t_g / t_m) * 100.0
                );
                results.push((format!("a4_mpi_{label}_w{w}"), t_m));
                results.push((format!("a4_gaspi_{label}_w{w}"), t_g));
            }
        }
        println!("  expected: GASPI gain grows with the communication share (small");
        println!("  blocks / many nodes); compute-bound blocks see little change.");
    }
    common::save_json("ablations.json", &results);
}
