#!/usr/bin/env bash
# Bench regression gate: diff a fresh perf_probe snapshot against the
# previous PR's baseline and fail past a tolerance.
#
#   scripts/bench_gate.sh <fresh.json> [baseline.json]
#
# The baseline resolves as: $2, else $BENCH_BASELINE, else
# rust/bench_results/BENCH_PR5.json (the PR-5 snapshot, when a local
# checkout still has one lying around). A missing baseline SKIPs the
# gate (exit 0) — the first run on a fresh machine has nothing to
# compare against; it still records the new snapshot for the next run.
#
# Direction is inferred from the metric name:
#   *_ms, *_secs, *padding_ratio          lower is better
#   *throughput*, *qps*, *per_sec*, *hit_rate*   higher is better
# Anything else is informational (printed, never gated).
#
# Tolerance: a metric fails when it is worse than baseline by more than
# BENCH_GATE_TOL x (default 2.0 — bench runners are noisy; the gate is
# for order-of-magnitude regressions, not jitter).

set -euo pipefail

FRESH="${1:?usage: bench_gate.sh <fresh.json> [baseline.json]}"
BASE="${2:-${BENCH_BASELINE:-rust/bench_results/BENCH_PR5.json}}"
TOL="${BENCH_GATE_TOL:-2.0}"

if [[ ! -f "$FRESH" ]]; then
  echo "bench_gate: fresh snapshot '$FRESH' not found" >&2
  exit 1
fi
if [[ ! -f "$BASE" ]]; then
  echo "bench_gate: SKIP (no baseline at '$BASE')"
  exit 0
fi

echo "bench_gate: $FRESH vs $BASE (tolerance ${TOL}x)"

# flatten {"key": num, ...} into "key value" lines (flat JSON only)
flat() {
  tr -d '{}",' <"$1" | awk -F: 'NF == 2 {
    gsub(/^[ \t]+|[ \t]+$/, "", $1); gsub(/^[ \t]+|[ \t]+$/, "", $2);
    if ($2 ~ /^-?[0-9]+([.][0-9]*)?([eE][+-]?[0-9]+)?$/) print $1, $2
  }'
}

FAIL=0
while read -r key fresh_v; do
  base_v=$(flat "$BASE" | awk -v k="$key" '$1 == k { print $2 }')
  [[ -z "$base_v" ]] && { printf '  %-32s %12g  (new metric)\n' "$key" "$fresh_v"; continue; }
  verdict=$(awk -v k="$key" -v f="$fresh_v" -v b="$base_v" -v tol="$TOL" 'BEGIN {
    dir = "info"
    if (k ~ /_ms$/ || k ~ /_secs$/ || k ~ /padding_ratio/) dir = "lower"
    if (k ~ /throughput/ || k ~ /qps/ || k ~ /per_sec/ || k ~ /hit_rate/) dir = "higher"
    if (dir == "info" || b == 0 || f == 0) { print "info"; exit }
    if (dir == "lower") ratio = f / b; else ratio = b / f
    if (ratio > tol) print "FAIL"; else print "ok"
  }')
  printf '  %-32s %12g  (base %g)  %s\n' "$key" "$fresh_v" "$base_v" "$verdict"
  [[ "$verdict" == "FAIL" ]] && FAIL=1
done < <(flat "$FRESH")

if [[ "$FAIL" -ne 0 ]]; then
  echo "bench_gate: FAIL — at least one metric regressed past ${TOL}x" >&2
  exit 1
fi
echo "bench_gate: ok"
