#!/usr/bin/env bash
# Out-of-core drill (the CI `out-of-core` job).
#
# The assertion logic lives in the declarative scenario twin
# `scenarios/out_of_core.json` (same dataset/config as before): ingest to
# a shard store, train resident and store-backed with an eviction-forcing
# 4 KiB cache budget, require evictions > 0 and the two posteriors
# bit-for-bit identical. This script only contributes what a scenario
# file cannot express: the hard address-space cap (ulimit -v) proving the
# store-backed leg really runs inside bounded memory.
#
# Run from the repository root after `cargo build --release`:
#
#   bash scripts/out_of_core_drill.sh
set -euo pipefail

BIN=${BIN:-rust/target/release/bmf-pp}

echo "== out-of-core scenario under a 1 GiB address-space cap"
(
  ulimit -v 1048576
  exec "$BIN" scenario scenarios/out_of_core.json
)
echo "PASS: store ≡ resident bitwise with evictions, inside the ulimit"
