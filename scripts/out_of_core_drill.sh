#!/usr/bin/env bash
# Out-of-core drill (the CI `out-of-core` job):
#
#   1. ingest a synthetic dataset into a per-block shard store, writing
#      the train/holdout split's holdout CSV alongside
#   2. run `bmf-pp train` resident (same flags) as the reference: save
#      the model and record its test RMSE
#   3. run `bmf-pp train --store` on the shard store under a hard
#      address-space cap (ulimit -v) with a cache budget far below the
#      store size, scoring the same holdout
#   4. require: evictions > 0 (the working set really was bounded), the
#      two RMSE values identical, and the two saved models byte-identical
#      — out-of-core is the same computation, not an approximation
#
# Run from the repository root after `cargo build --release`:
#
#   bash scripts/out_of_core_drill.sh
set -euo pipefail

BIN=${BIN:-rust/target/release/bmf-pp}
WORK=$(mktemp -d "${TMPDIR:-/tmp}/bmfpp_ooc.XXXXXX")
trap 'rm -rf "$WORK"' EXIT

# one dataset + config for every run; --tau is explicit because a
# store-backed run cannot derive auto_tau from resident ratings, and
# --seed lives in CFG_FLAGS only (it seeds both the synthetic generator
# and the sampler, and must match across all three invocations)
DATA_FLAGS=(--dataset movielens --scale 0.003)
CFG_FLAGS=(--grid 3x3 --burnin 6 --samples 16 --native --tau 1.5
           --seed 11 --workers 1 --quiet)

echo "== 1/4: ingest into a shard store (3x3 grid) + save the holdout"
INGEST_OUT="$WORK/ingest.log"
"$BIN" ingest "${DATA_FLAGS[@]}" --seed 11 --grid 3x3 --out "$WORK/shards" \
  --save-test "$WORK/holdout.csv" | tee "$INGEST_OUT"
STORE_BYTES=$(grep -o '[0-9]* bytes' "$INGEST_OUT" | head -1 | awk '{print $1}')
echo "   store size: ${STORE_BYTES:-?} bytes"

echo "== 2/4: resident reference run"
REF_OUT="$WORK/resident.log"
"$BIN" train "${DATA_FLAGS[@]}" "${CFG_FLAGS[@]}" \
  --save "$WORK/reference.json" | tee "$REF_OUT"
REF_RMSE=$(sed -n 's/.*test RMSE = \([0-9.]*\).*/\1/p' "$REF_OUT")
[[ -n "$REF_RMSE" ]] || { echo "FAIL: resident run printed no RMSE" >&2; exit 1; }

echo "== 3/4: store-backed run, 4 KiB cache budget, 1 GiB address-space cap"
OOC_OUT="$WORK/store.log"
(
  ulimit -v 1048576
  exec "$BIN" train --store "$WORK/shards" --test-file "$WORK/holdout.csv" \
    --cache-bytes 4096 "${CFG_FLAGS[@]}" --save "$WORK/store.json"
) | tee "$OOC_OUT"
OOC_RMSE=$(sed -n 's/.*test RMSE = \([0-9.]*\).*/\1/p' "$OOC_OUT")
EVICTIONS=$(grep -o '[0-9]* evictions' "$OOC_OUT" | awk '{print $1}')
[[ -n "$OOC_RMSE" ]] || { echo "FAIL: store run printed no RMSE" >&2; exit 1; }

echo "== 4/4: verdicts"
if [[ -z "${EVICTIONS:-}" || "$EVICTIONS" -eq 0 ]]; then
  echo "FAIL: no evictions — the cache budget never bounded the working set" >&2
  exit 1
fi
echo "   evictions: $EVICTIONS (budget 4096 of ${STORE_BYTES} store bytes)"
if [[ "$REF_RMSE" != "$OOC_RMSE" ]]; then
  echo "FAIL: RMSE diverged (resident $REF_RMSE vs store-backed $OOC_RMSE)" >&2
  exit 1
fi
echo "   RMSE identical: $REF_RMSE"
if cmp -s "$WORK/reference.json" "$WORK/store.json"; then
  echo "PASS: store-backed posterior is byte-identical to the resident run"
else
  echo "FAIL: store-backed model differs from the resident reference" >&2
  cmp "$WORK/reference.json" "$WORK/store.json" | head -5 >&2 || true
  exit 1
fi
