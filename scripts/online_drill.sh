#!/usr/bin/env bash
# End-to-end online-update drill (the CI `online-loop` job) — the full
# serve → collect → retrain → hot-swap loop through the real binary:
#
#   1. ingest a dataset into a shard store and train from it with
#      --checkpoint-every 1 --checkpoint-dir (the prior generation)
#   2. start `bmf-pp serve --checkpoint-dir` and record the serving
#      generation from /stats
#   3. "collect" new ratings as a delta CSV and fold it into the store
#      with `ingest --append` (manifest revision bumps, dirty shards
#      rewritten in place)
#   4. `bmf-pp update --store`: re-sample only the dirty blocks, seeding
#      everything else from the prior checkpoint, writing a new
#      generation into the same directory
#   5. hammer /predict throughout and wait for /stats to report the newer
#      generation — the hot-swap must land with zero dropped requests
#
# Run from the repository root after `cargo build --release`:
#
#   bash scripts/online_drill.sh
set -euo pipefail

BIN=${BIN:-rust/target/release/bmf-pp}
PORT=${PORT:-7981}
BASE="http://127.0.0.1:$PORT"
WORK=$(mktemp -d "${TMPDIR:-/tmp}/bmfpp_online_drill.XXXXXX")
SERVE_PID=
HAMMER_PID=
cleanup() {
  if [ -n "$HAMMER_PID" ]; then kill "$HAMMER_PID" 2>/dev/null || true; fi
  if [ -n "$SERVE_PID" ]; then kill "$SERVE_PID" 2>/dev/null || true; fi
  rm -rf "$WORK"
}
trap cleanup EXIT

SHARDS="$WORK/shards"
CKPTS="$WORK/ckpts"
DELTA="$WORK/delta.csv"
DROPS="$WORK/drops"

echo "== 1/5: ingest + store-backed train into $CKPTS"
"$BIN" ingest --dataset movielens --scale 0.002 --seed 21 \
  --grid 2x2 --out "$SHARDS"
"$BIN" train --store "$SHARDS" --tau 1.5 --burnin 4 --samples 10 \
  --native --workers 1 --quiet --seed 21 \
  --checkpoint-every 1 --checkpoint-dir "$CKPTS"

echo "== 2/5: start bmf-pp serve on $BASE"
"$BIN" serve --checkpoint-dir "$CKPTS" --addr "127.0.0.1:$PORT" --poll-ms 100 &
SERVE_PID=$!
for _ in $(seq 1 100); do
  if curl -sf "$BASE/healthz" > /dev/null 2>&1; then break; fi
  if ! kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "FAIL: serve exited before answering /healthz" >&2
    exit 1
  fi
  sleep 0.1
done
curl -sf "$BASE/healthz" | grep -q '"ok":true'
GEN0=$(curl -sf "$BASE/stats" | sed -n 's/.*"generation":"\([0-9]*\)".*/\1/p')
if [ -z "$GEN0" ]; then
  echo "FAIL: /stats did not report a generation" >&2
  exit 1
fi
echo "   serving generation $GEN0"

# hammer /predict for the rest of the drill; every failure is a dropped
# request and fails the run
: > "$DROPS"
(
  while :; do
    curl -sf "$BASE/predict?row=0&col=0" > /dev/null 2>&1 || echo drop >> "$DROPS"
    sleep 0.02
  done
) &
HAMMER_PID=$!

echo "== 3/5: collect a delta and fold it into the store"
printf 'row,col,value\n0,0,4.5\n1,2,1.0\n2,1,3.5\n' > "$DELTA"
"$BIN" ingest --append --delta "$DELTA" --out "$SHARDS" | tee "$WORK/append.log"
grep -q 'manifest revision 1' "$WORK/append.log"

echo "== 4/5: incremental update from the prior checkpoint"
"$BIN" update --from "$CKPTS" --store "$SHARDS" --delta "$DELTA" \
  --tau 1.5 --burnin 4 --samples 10 --native --workers 1 --quiet \
  | tee "$WORK/update.log"
grep -q 'passed through clean' "$WORK/update.log"

echo "== 5/5: wait for the hot-swap, require zero dropped requests"
GEN1="$GEN0"
for _ in $(seq 1 300); do
  GEN1=$(curl -sf "$BASE/stats" | sed -n 's/.*"generation":"\([0-9]*\)".*/\1/p')
  if [ -n "$GEN1" ] && [ "$GEN1" -gt "$GEN0" ]; then break; fi
  sleep 0.1
done
if [ -z "$GEN1" ] || [ "$GEN1" -le "$GEN0" ]; then
  echo "FAIL: updated generation never swapped in (still $GEN1)" >&2
  exit 1
fi
kill "$HAMMER_PID" 2>/dev/null || true
wait "$HAMMER_PID" 2>/dev/null || true
HAMMER_PID=
DROPPED=$(wc -l < "$DROPS")
if [ "$DROPPED" -ne 0 ]; then
  echo "FAIL: $DROPPED request(s) dropped during the update/swap" >&2
  exit 1
fi
curl -sf "$BASE/predict?row=0&col=0" | grep -q '"value":'
curl -sf -X POST "$BASE/shutdown" | grep -q '"stopping":true'
wait "$SERVE_PID"
SERVE_PID=
echo "PASS: online drill (swap $GEN0 -> $GEN1, 0 dropped requests)"
