#!/usr/bin/env bash
# End-to-end crash drill (the CI `recovery` job):
#
#   1. run `bmf-pp train` uninterrupted and save the model (reference)
#   2. run the same config with --checkpoint-every 1 --checkpoint-dir,
#      SIGKILL the process as soon as the first generation file appears
#   3. resume from the checkpoint DIRECTORY (newest valid generation)
#      and save the model again
#   4. require the two saved models to be byte-identical: the posterior
#      survived a hard kill bitwise, generations + atomic renames and all
#
# Run from the repository root after `cargo build --release`:
#
#   bash scripts/recovery_drill.sh
set -euo pipefail

BIN=${BIN:-rust/target/release/bmf-pp}
WORK=$(mktemp -d "${TMPDIR:-/tmp}/bmfpp_recovery.XXXXXX")
trap 'rm -rf "$WORK"' EXIT

# one fixed config for all three runs; big enough that the kill lands
# mid-run, small enough to finish in seconds
TRAIN_FLAGS=(--dataset movielens --scale 0.003 --grid 3x3 --burnin 6
             --samples 16 --native --seed 11 --workers 1 --quiet)

echo "== 1/4: uninterrupted reference run"
"$BIN" train "${TRAIN_FLAGS[@]}" --save "$WORK/reference.json"

echo "== 2/4: crash run (checkpoint-every=1, SIGKILL at first generation)"
CKPTS="$WORK/ckpts"
"$BIN" train "${TRAIN_FLAGS[@]}" \
  --checkpoint-every 1 --checkpoint-dir "$CKPTS" &
PID=$!

# wait (max ~60s) for the first generation file, then kill -9 mid-run
for _ in $(seq 1 600); do
  if compgen -G "$CKPTS/partial-gen-*.json" > /dev/null; then break; fi
  if ! kill -0 "$PID" 2>/dev/null; then break; fi
  sleep 0.1
done
if ! compgen -G "$CKPTS/partial-gen-*.json" > /dev/null; then
  echo "FAIL: no checkpoint generation appeared before the run ended" >&2
  wait "$PID" || true
  exit 1
fi
if kill -9 "$PID" 2>/dev/null; then
  echo "   SIGKILLed pid $PID after $(ls "$CKPTS" | wc -l) generation file(s)"
else
  # the run beat the kill — rare on CI hardware, but the resume below
  # still proves generation discovery; note it loudly
  echo "   WARN: run finished before SIGKILL landed; resume covers a completed dir"
fi
wait "$PID" 2>/dev/null || true

echo "== 3/4: resume from the checkpoint directory (newest valid generation)"
RESUME_OUT="$WORK/resume.log"
"$BIN" train "${TRAIN_FLAGS[@]}" \
  --resume "$CKPTS" --save "$WORK/resumed.json" | tee "$RESUME_OUT"
grep -q "blocks restored from checkpoint" "$RESUME_OUT" || {
  echo "FAIL: resume did not restore any blocks" >&2
  exit 1
}

echo "== 4/4: bitwise comparison of the saved posteriors"
if cmp -s "$WORK/reference.json" "$WORK/resumed.json"; then
  echo "PASS: resumed posterior is byte-identical to the uninterrupted run"
else
  echo "FAIL: resumed model differs from the uninterrupted reference" >&2
  cmp "$WORK/reference.json" "$WORK/resumed.json" | head -5 >&2 || true
  exit 1
fi
