#!/usr/bin/env bash
# End-to-end crash drill (the CI `recovery` job).
#
# Step 1 delegates the deterministic crash→resume assertion to the
# declarative scenario twin `scenarios/crash_resume.json` (same
# dataset/config as before): inject a panic mid-run with
# checkpoint-every=1, resume from the newest generation, require blocks
# restored and the posterior bit-for-bit identical to the uninterrupted
# reference. Step 2 keeps the one thing a scenario file cannot express:
# a real SIGKILL of the whole process — no unwinding, no atexit — then a
# directory resume proving the atomically-renamed generations survive a
# hard kill.
#
# Run from the repository root after `cargo build --release`:
#
#   bash scripts/recovery_drill.sh
set -euo pipefail

BIN=${BIN:-rust/target/release/bmf-pp}
WORK=$(mktemp -d "${TMPDIR:-/tmp}/bmfpp_recovery.XXXXXX")
trap 'rm -rf "$WORK"' EXIT

echo "== 1/2: deterministic crash→resume scenario (panic fault, bitwise resume)"
"$BIN" scenario scenarios/crash_resume.json

echo "== 2/2: real SIGKILL mid-run, then resume from the generation directory"
# big enough that the kill lands mid-run, small enough to finish in seconds
TRAIN_FLAGS=(--dataset movielens --scale 0.003 --grid 3x3 --burnin 6
             --samples 16 --native --seed 11 --workers 1 --quiet)
CKPTS="$WORK/ckpts"
"$BIN" train "${TRAIN_FLAGS[@]}" \
  --checkpoint-every 1 --checkpoint-dir "$CKPTS" &
PID=$!

# wait (max ~60s) for the first generation file, then kill -9 mid-run
for _ in $(seq 1 600); do
  if compgen -G "$CKPTS/partial-gen-*.json" > /dev/null; then break; fi
  if ! kill -0 "$PID" 2>/dev/null; then break; fi
  sleep 0.1
done
if ! compgen -G "$CKPTS/partial-gen-*.json" > /dev/null; then
  echo "FAIL: no checkpoint generation appeared before the run ended" >&2
  wait "$PID" || true
  exit 1
fi
if kill -9 "$PID" 2>/dev/null; then
  echo "   SIGKILLed pid $PID after $(ls "$CKPTS" | wc -l) generation file(s)"
else
  # the run beat the kill — rare on CI hardware, but the resume below
  # still proves generation discovery; note it loudly
  echo "   WARN: run finished before SIGKILL landed; resume covers a completed dir"
fi
wait "$PID" 2>/dev/null || true

RESUME_OUT="$WORK/resume.log"
"$BIN" train "${TRAIN_FLAGS[@]}" \
  --resume "$CKPTS" --save "$WORK/resumed.json" | tee "$RESUME_OUT"
grep -q "blocks restored from checkpoint" "$RESUME_OUT" || {
  echo "FAIL: resume did not restore any blocks" >&2
  exit 1
}
echo "PASS: SIGKILLed run resumed from its generation directory"
