#!/usr/bin/env bash
# End-to-end serve smoke (the CI `serve-smoke` job):
#
#   1. train with --checkpoint-every 1 --checkpoint-dir: the newest
#      generation of a finished run holds every grid block, so it is
#      servable
#   2. start `bmf-pp serve --checkpoint-dir` in the background
#   3. exercise /healthz /predict /top /stats over real HTTP and record
#      the serving generation (malformed/out-of-range requests must be
#      typed 4xx, not hangups)
#   4. retrain into the same directory (generation numbering continues
#      past existing files) and wait for /stats to report the newer
#      generation — the hot-swap — then drop a corrupt "newest" file and
#      require the server to keep serving the last good generation
#   5. POST /shutdown and require a clean exit
#
# Run from the repository root after `cargo build --release`:
#
#   bash scripts/serve_smoke.sh
set -euo pipefail

BIN=${BIN:-rust/target/release/bmf-pp}
PORT=${PORT:-7979}
BASE="http://127.0.0.1:$PORT"
WORK=$(mktemp -d "${TMPDIR:-/tmp}/bmfpp_serve_smoke.XXXXXX")
SERVE_PID=
cleanup() {
  if [ -n "$SERVE_PID" ]; then kill "$SERVE_PID" 2>/dev/null || true; fi
  rm -rf "$WORK"
}
trap cleanup EXIT

TRAIN_FLAGS=(--dataset movielens --scale 0.002 --grid 2x2 --burnin 4
             --samples 10 --native --workers 1 --quiet)
CKPTS="$WORK/ckpts"

echo "== 1/5: train a servable generation into $CKPTS"
"$BIN" train "${TRAIN_FLAGS[@]}" --seed 21 \
  --checkpoint-every 1 --checkpoint-dir "$CKPTS"

echo "== 2/5: start bmf-pp serve on $BASE"
"$BIN" serve --checkpoint-dir "$CKPTS" --addr "127.0.0.1:$PORT" --poll-ms 100 &
SERVE_PID=$!
for _ in $(seq 1 100); do
  if curl -sf "$BASE/healthz" > /dev/null 2>&1; then break; fi
  if ! kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "FAIL: serve exited before answering /healthz" >&2
    exit 1
  fi
  sleep 0.1
done
curl -sf "$BASE/healthz" | grep -q '"ok":true'

echo "== 3/5: exercise the endpoints"
curl -sf "$BASE/predict?row=0&col=0&variance" > "$WORK/predict.json"
grep -q '"value":' "$WORK/predict.json"
grep -q '"variance":' "$WORK/predict.json"
curl -sf "$BASE/top?row=0&n=3" | grep -q '"items":'
test "$(curl -s -o /dev/null -w '%{http_code}' "$BASE/predict?row=bad&col=0")" = 400
test "$(curl -s -o /dev/null -w '%{http_code}' "$BASE/predict?row=99999999&col=0")" = 404
test "$(curl -s -o /dev/null -w '%{http_code}' "$BASE/nope")" = 404
GEN0=$(curl -sf "$BASE/stats" | sed -n 's/.*"generation":"\([0-9]*\)".*/\1/p')
if [ -z "$GEN0" ]; then
  echo "FAIL: /stats did not report a generation" >&2
  exit 1
fi
echo "   serving generation $GEN0"

echo "== 4/5: retrain into the same dir and wait for the hot-swap"
"$BIN" train "${TRAIN_FLAGS[@]}" --seed 22 \
  --checkpoint-every 1 --checkpoint-dir "$CKPTS"
GEN1="$GEN0"
for _ in $(seq 1 300); do
  GEN1=$(curl -sf "$BASE/stats" | sed -n 's/.*"generation":"\([0-9]*\)".*/\1/p')
  if [ -n "$GEN1" ] && [ "$GEN1" -gt "$GEN0" ]; then break; fi
  sleep 0.1
done
if [ -z "$GEN1" ] || [ "$GEN1" -le "$GEN0" ]; then
  echo "FAIL: hot-swap never landed (still generation $GEN1)" >&2
  exit 1
fi
echo "   hot-swapped $GEN0 -> $GEN1 with zero downtime"

# a corrupt newest generation must be skipped, never served
echo "not json" > "$CKPTS/partial-gen-99999999.json"
sleep 0.5
GEN2=$(curl -sf "$BASE/stats" | sed -n 's/.*"generation":"\([0-9]*\)".*/\1/p')
if [ "$GEN2" != "$GEN1" ]; then
  echo "FAIL: corrupt generation changed the served model ($GEN1 -> $GEN2)" >&2
  exit 1
fi
curl -sf "$BASE/predict?row=0&col=0" | grep -q '"value":'
echo "   corrupt newest generation skipped, still serving $GEN2"

echo "== 5/5: clean shutdown"
curl -sf -X POST "$BASE/shutdown" | grep -q '"stopping":true'
wait "$SERVE_PID"
SERVE_PID=
echo "PASS: serve smoke (swap $GEN0 -> $GEN1, corrupt generation skipped)"
