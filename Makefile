# Build-time AOT lowering: compiles the L2/L1 Gibbs programs (JAX/Pallas)
# to HLO text + manifest under rust/artifacts, where the PJRT runtime
# (`--features pjrt`) picks them up. Without the artifacts the coordinator
# transparently uses the native sampler — all default tests still pass.

.PHONY: artifacts test bench scenarios clean-artifacts

artifacts:
	cd python && python -m compile.aot --out-dir ../rust/artifacts

test:
	cd rust && cargo test -q
	python -m pytest python/tests -q

# run every declarative end-to-end spec under scenarios/ (release build)
scenarios:
	cd rust && cargo build --release
	rust/target/release/bmf-pp scenario scenarios/ --report scenario_report.json

bench:
	cd rust && cargo bench --no-run

clean-artifacts:
	rm -rf rust/artifacts
